"""Crash-consistent live ingestion over the immutable index tiers
(DESIGN.md §12).

`MutableIndex` is the streaming-mutability subsystem the ROADMAP's
north star needs: the build-time artifacts (HNSW graph, ScaNN leaves,
SQ8 shadows) stay immutable, and live mutation flows through three
coupled mechanisms —

  insert  -> WAL record, fsync, then append to the LSM delta tier
             (storage/delta.py) — an unindexed capacity-padded segment
             scanned exactly by core.executor.DeltaExecutor;
  delete  -> WAL record, fsync, then a tombstone bit — composed into
             every query's filter bitmap (types.bitmap_andnot), so the
             row vanishes from all strategies without touching an index;
  search  -> any base executor's top-k over [0, base_n) merged with the
             delta scan's top-k via types.merge_topk — bit-identical to
             a from-scratch oracle over the union (`MergedResult`);
  compact -> fold the delta into a rebuilt base (new ScaNN leaves, new
             graph, re-calibrated SQ8 quantizer for drift), save a FULL
             checkpoint, then log a COMPACT marker.

Durability protocol (WAL rules): a mutation is applied to memory only
after its record is durably fsynced; `recover()` = restore the latest
checkpoint, then replay WAL records with lsn > the checkpoint's
applied_lsn.  The deterministic crash harness (tests/test_wal_recovery)
kills this pipeline at every record byte boundary and asserts recovered
search results are bit-identical to a reference that saw the same
durable prefix.

Id space is append-only and stable: base rows keep ids [0, base_n),
delta rows get base_n + local, compaction grows the base underneath the
same ids, and deletes never reclaim ids (the tombstone is forever —
dead rows ride through compaction masked, and are pruned from rebuilt
ScaNN leaf postings).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Optional

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import (latest_step, read_manifest,
                                    restore_checkpoint, save_checkpoint)
from repro.core.executor import DeltaExecutor, make_executor
from repro.core.hnsw import build_graph
from repro.core.scann import build_scann
from repro.core.types import (SearchParams, SearchResult, VectorStore,
                              bitset_words, merge_topk, quantize_store)
from repro.storage import wal as W
from repro.storage.delta import DeltaTier, Tombstones
from repro.storage.engine import StorageEngine, make_storage_engine
from repro.storage.faults import FaultInjector, FaultPlan


@dataclasses.dataclass
class MergedResult:
    """A base executor's answer fused with the delta tier's exact scan.

    dists/ids are the merged (Q, k) top-k; `stats` sums both legs'
    SearchStats (the delta leg's seqscan counters ride on top of the base
    strategy's); `base`/`delta` keep the full per-leg SearchResults for
    storage/anytime introspection."""

    dists: Any
    ids: Any
    stats: Any
    strategy: str
    base: SearchResult
    delta: SearchResult


def _clip_bitmap(words: np.ndarray, n: int) -> np.ndarray:
    """Zero every bit >= n (and return a copy) — the base executors' view
    of a capacity-wide bitmap must not count delta-row bits."""
    out = np.array(words, np.uint32, copy=True)
    nw = bitset_words(n)
    out[..., nw:] = 0
    rem = n & 31
    if rem:
        out[..., nw - 1] &= np.uint32((1 << rem) - 1)
    return out


class MutableIndex:
    """WAL-backed mutable vector index: immutable base tiers + LSM delta
    tier + tombstones, with checkpointed compaction and crash recovery.

    `capacity` bounds the TOTAL id space ever allocated (base + all
    inserts across all compactions) — filter bitmaps are sized to it once
    and stay jit-shape-stable for the index's whole life.  Mutations are
    applied only after their WAL record is fsynced; write-path faults
    (FaultPlan.wal_torn_prob / fsync_fail_prob) surface as
    WalTornWrite/WalSyncError with the in-memory state deterministically
    NOT advanced (the failed batch was simply never written).
    """

    def __init__(self, base_vectors: np.ndarray, wal_path: str,
                 ckpt_dir: str, *, metric: str = "l2",
                 capacity: Optional[int] = None,
                 delta_capacity: int = 256,
                 num_leaves: int = 16, graph_m: int = 12,
                 ef_construction: int = 48, seed: int = 0,
                 with_graph: bool = True, with_scann: bool = True,
                 with_storage: bool = False,
                 storage_capacity_frac: float = 0.5,
                 wal_pages: int = 64,
                 faults: Optional[FaultPlan] = None,
                 _defer_build: bool = False):
        base_vectors = np.asarray(base_vectors, np.float32)
        self.metric = metric
        self.delta_capacity = int(delta_capacity)
        self.capacity = int(capacity if capacity is not None
                            else base_vectors.shape[0]
                            + 4 * self.delta_capacity)
        self.num_leaves = num_leaves
        self.graph_m = graph_m
        self.ef_construction = ef_construction
        self.seed = seed
        self.with_graph = with_graph
        self.with_scann = with_scann
        self.with_storage = with_storage
        self.storage_capacity_frac = storage_capacity_frac
        self.wal_pages_budget = wal_pages
        self.faults = faults
        self.wal_path = wal_path
        self.ckpt_dir = ckpt_dir

        self._injector = (FaultInjector(faults)
                          if faults is not None and faults.active else None)
        self.applied_lsn = 0
        self._ckpt_step = 0
        self.compactions = 0
        # cumulative logical bytes the USER asked to write (the
        # write-amplification denominator)
        self.user_bytes = 0

        if not _defer_build:
            self._build_base(base_vectors)
            self.delta = DeltaTier(base_n=self.base_n,
                                   capacity=self.delta_capacity,
                                   dim=base_vectors.shape[1])
            self.tombstones = Tombstones(self.capacity)
            self._open_wal()

    # -- construction internals ---------------------------------------------
    def _build_base(self, vectors: np.ndarray) -> None:
        """(Re)build every base tier from `vectors` — used at init, after
        compaction, and during recovery.  Deterministic given (vectors,
        seed): recovery rebuilds the exact artifacts the crashed process
        had."""
        self.store = quantize_store(VectorStore.build(vectors, self.metric))
        self.scann = (build_scann(self.store, self.num_leaves,
                                  seed=self.seed)
                      if self.with_scann else None)
        self.graph = (build_graph(self.store, m=self.graph_m,
                                  ef_construction=self.ef_construction,
                                  seed=self.seed)
                      if self.with_graph else None)
        self._executors: dict[str, Any] = {}
        self.engine: Optional[StorageEngine] = None
        if self.with_storage:
            self.engine = make_storage_engine(
                self.store, self.scann, self.graph,
                capacity_frac=self.storage_capacity_frac,
                delta_capacity=self.delta_capacity,
                wal_pages=self.wal_pages_budget)
            if self._injector is not None:
                self.engine.pool.faults = self._injector

    def _open_wal(self) -> None:
        hook = None
        if self.engine is not None:
            def hook(offset, nbytes, kind):
                if kind == "append":
                    self.engine.account_wal_append(offset, nbytes)
                else:
                    self.engine.account_wal_sync()
        self.wal = W.WriteAheadLog(self.wal_path, faults=self._injector,
                                   page_hook=hook)

    @property
    def base_n(self) -> int:
        return int(self.store.n)

    @property
    def live_count(self) -> int:
        return self.base_n + self.delta.count - self.tombstones.count

    def words(self) -> int:
        """Filter-bitmap word count callers must size to (fixed for
        life)."""
        return bitset_words(self.capacity)

    # -- the durability choke point -----------------------------------------
    def _log(self, kind: int, payload: bytes) -> W.WalRecord:
        """Append + fsync one record; memory is mutated only after this
        returns.  Injected write faults leave the log in a deterministic
        clean state (torn fragment discarded / un-synced tail rolled
        back) and re-raise — the mutation never happened."""
        try:
            rec = self.wal.append(kind, payload)
        except W.WalTornWrite:
            self.wal.discard_torn()
            raise
        try:
            self.wal.sync()
        except W.WalSyncError:
            self.wal.rollback_to_durable()
            raise
        return rec

    # -- mutation API -------------------------------------------------------
    def insert(self, rows: np.ndarray) -> np.ndarray:
        """Durably insert a batch; returns the new global ids.  Auto-
        compacts first when the delta tier cannot hold the batch."""
        rows = np.asarray(rows, np.float32)
        if rows.ndim != 2 or rows.shape[1] != self.store.dim:
            raise ValueError(f"expected (m, {self.store.dim}) rows, got "
                             f"{rows.shape}")
        m = rows.shape[0]
        if m > self.delta_capacity:
            raise ValueError(f"batch of {m} exceeds delta capacity "
                             f"{self.delta_capacity}")
        if self.delta.count + m > self.delta_capacity:
            self.compact()
        start = self.base_n + self.delta.count
        if start + m > self.capacity:
            raise ValueError(f"id space exhausted: {start}+{m} > capacity "
                             f"{self.capacity}")
        rec = self._log(W.REC_INSERT, W.encode_insert(start, rows))
        local_lo = self.delta.count
        ids = self.delta.append(rows)
        self.applied_lsn = rec.lsn
        self.user_bytes += int(rows.nbytes)
        if self.engine is not None:
            self.engine.account_delta_write(
                np.arange(local_lo, local_lo + m))
        return ids

    def delete(self, ids: np.ndarray) -> int:
        """Durably tombstone ids; returns how many were newly dead.
        Deleting an id that was never allocated is an error; deleting a
        dead id is an idempotent no-op (still logged — replay is
        idempotent too)."""
        ids = np.unique(np.asarray(ids, np.int64))
        if ids.size and (ids.min() < 0
                         or ids.max() >= self.base_n + self.delta.count):
            raise ValueError("delete of unallocated id")
        rec = self._log(W.REC_DELETE, W.encode_delete(ids))
        newly = self.tombstones.mark(ids)
        self.applied_lsn = rec.lsn
        self.user_bytes += int(ids.nbytes)
        if self.engine is not None and ids.size:
            self.engine.account_tombstone_write(ids)
        return newly

    # -- search -------------------------------------------------------------
    def _executor(self, method: str):
        if method not in self._executors:
            self._executors[method] = make_executor(
                method, self.store, graph=self.graph, index=self.scann,
                storage=self.engine)
        return self._executors[method]

    def _delta_executor(self) -> DeltaExecutor:
        if "delta" not in self._executors:
            self._executors["delta"] = DeltaExecutor(
                self.delta, self.metric, storage=self.engine)
        return self._executors["delta"]

    def search(self, queries, bitmaps, params: SearchParams,
               method: str = "bruteforce") -> MergedResult:
        """Filtered top-k over base + delta − tombstones.

        `bitmaps` (Q, words(capacity)) packed filter bitmaps over GLOBAL
        ids (narrower bitmaps are zero-padded: rows the filter predates
        don't pass).  The tombstone bitmap is AND-NOT-composed first, so
        every strategy sees deletes identically; the base executor runs
        on the bits < base_n, the delta scan on the full live bitmap, and
        the two top-k sets merge bit-identically to a from-scratch
        oracle (base-first concat == id-ascending tie order)."""
        bm = np.asarray(bitmaps, np.uint32)
        w = self.words()
        if bm.shape[-1] < w:
            bm = np.concatenate(
                [bm, np.zeros(bm.shape[:-1] + (w - bm.shape[-1],),
                              np.uint32)], -1)
        live = self.tombstones.live_mask(bm)
        base_bm = jnp.asarray(
            _clip_bitmap(live, self.base_n)[..., :bitset_words(self.base_n)])
        base_res = self._executor(method).search(
            jnp.asarray(queries), base_bm, params)
        delta_res = self._delta_executor().search(
            jnp.asarray(queries), jnp.asarray(live), params)
        dists, ids = merge_topk(base_res.dists, base_res.ids,
                                delta_res.dists, delta_res.ids, params.k)
        return MergedResult(dists=dists, ids=ids,
                            stats=base_res.stats + delta_res.stats,
                            strategy=method, base=base_res,
                            delta=delta_res)

    # -- checkpoint / compaction --------------------------------------------
    def _state_tree(self) -> dict:
        return {"base": np.asarray(self.store.vectors),
                "delta": self.delta.vectors.copy(),
                "tomb": self.tombstones.words.copy()}

    def _state_extra(self, kind: str) -> dict:
        return {"kind": kind, "base_n": self.base_n,
                "count": int(self.delta.count),
                "applied_lsn": int(self.applied_lsn),
                "capacity": self.capacity,
                "delta_capacity": self.delta_capacity,
                "compactions": self.compactions}

    def checkpoint(self) -> int:
        """Durably snapshot (base, delta, tombstones) + applied_lsn;
        recovery replays only WAL records past it.  Returns the step."""
        self._ckpt_step += 1
        save_checkpoint(self.ckpt_dir, self._ckpt_step, self._state_tree(),
                        extra=self._state_extra("delta"), fsync=True)
        self._log(W.REC_CHECKPOINT,
                  W.encode_meta({"step": self._ckpt_step,
                                 "applied_lsn": int(self.applied_lsn)}))
        if self.engine is not None:
            self.engine.account_checkpoint(self.delta.count)
        return self._ckpt_step

    def compact(self) -> None:
        """Fold the delta tier into a rebuilt base: new base array (ids
        stable, tombstoned rows ride along dead), fresh ScaNN leaves with
        dead rows pruned from the postings, fresh graph, and an SQ8
        quantizer re-calibrated on the post-drift distribution.  Ordering
        is the crash-safety core: the FULL checkpoint of the folded state
        is durably saved BEFORE the COMPACT marker enters the WAL, so
        every crash point recovers deterministically (before the
        checkpoint -> replay rebuilds the pre-compaction state; after it
        -> the checkpoint IS the folded state and the marker is a
        no-op)."""
        count = self.delta.count
        if self.engine is not None:
            self.engine.account_compaction_read(count)
        new_base = np.concatenate(
            [np.asarray(self.store.vectors),
             self.delta.vectors[:count]], axis=0)
        self._build_base(new_base)           # scann/graph/SQ8 recalibrated
        if self.scann is not None:
            dead = self.tombstones.is_dead(
                np.maximum(np.asarray(self.scann.leaf_rowids), 0))
            pruned = np.where(dead & (np.asarray(self.scann.leaf_rowids)
                                      >= 0),
                              -1, np.asarray(self.scann.leaf_rowids))
            self.scann = dataclasses.replace(
                self.scann, leaf_rowids=jnp.asarray(pruned))
            self._executors.clear()          # executors captured old scann
        self.delta.reset(self.base_n)
        self.compactions += 1
        self._ckpt_step += 1
        save_checkpoint(self.ckpt_dir, self._ckpt_step, self._state_tree(),
                        extra=self._state_extra("full"), fsync=True)
        self._log(W.REC_COMPACT,
                  W.encode_meta({"step": self._ckpt_step,
                                 "base_n": self.base_n,
                                 "applied_lsn": int(self.applied_lsn)}))
        if self.engine is not None:
            self.engine.account_compaction_write()

    # -- recovery -----------------------------------------------------------
    @classmethod
    def recover(cls, base_vectors: np.ndarray, wal_path: str,
                ckpt_dir: str, **kwargs) -> "MutableIndex":
        """Reconstruct the index a crashed process left behind: restore
        the latest durable checkpoint (or the pristine base), reopen the
        WAL (truncating any torn tail via CRC), and replay records with
        lsn > the checkpoint's applied_lsn.  Deterministic: the same
        (base_vectors, seed, durable WAL prefix) always yields an index
        whose search results are bit-identical to a reference that
        executed the same durable prefix uncrashed."""
        base_vectors = np.asarray(base_vectors, np.float32)
        self = cls(base_vectors, wal_path, ckpt_dir, _defer_build=True,
                   **kwargs)
        step = latest_step(ckpt_dir)
        if step is not None:
            extra = read_manifest(ckpt_dir, step)["extra"]
            dim = base_vectors.shape[1]
            like = {"base": np.zeros((extra["base_n"], dim), np.float32),
                    "delta": np.zeros((extra["delta_capacity"], dim),
                                      np.float32),
                    "tomb": np.zeros(bitset_words(extra["capacity"]),
                                     np.uint32)}
            tree, _ = restore_checkpoint(ckpt_dir, step, like)
            self.capacity = int(extra["capacity"])
            self.delta_capacity = int(extra["delta_capacity"])
            self._build_base(np.asarray(tree["base"]))
            self.delta = DeltaTier(
                base_n=self.base_n, capacity=self.delta_capacity,
                dim=dim, count=int(extra["count"]),
                vectors=np.array(tree["delta"], np.float32))
            self.tombstones = Tombstones(self.capacity,
                                         words=np.asarray(tree["tomb"]))
            self.applied_lsn = int(extra["applied_lsn"])
            self._ckpt_step = step
            self.compactions = int(extra.get("compactions", 0))
        else:
            self._build_base(base_vectors)
            self.delta = DeltaTier(base_n=self.base_n,
                                   capacity=self.delta_capacity,
                                   dim=base_vectors.shape[1])
            self.tombstones = Tombstones(self.capacity)
        self._open_wal()
        for rec in self.wal.replay(from_lsn=self.applied_lsn):
            if rec.kind == W.REC_INSERT:
                start, vecs = W.decode_insert(rec.payload)
                expect = self.base_n + self.delta.count
                if start != expect:
                    raise W.WalCorruption(
                        f"insert record lsn {rec.lsn} starts at id "
                        f"{start}, expected {expect}")
                local_lo = self.delta.count
                self.delta.append(vecs)
                if self.engine is not None:
                    self.engine.account_delta_write(
                        np.arange(local_lo, local_lo + vecs.shape[0]))
                self.user_bytes += int(vecs.nbytes)
            elif rec.kind == W.REC_DELETE:
                ids = W.decode_delete(rec.payload)
                self.tombstones.mark(ids)
                if self.engine is not None and ids.size:
                    self.engine.account_tombstone_write(ids)
                self.user_bytes += int(ids.nbytes)
            # REC_CHECKPOINT / REC_COMPACT are markers: the state they
            # describe was restored from the checkpoint store already
            # (compaction durably checkpoints BEFORE logging its marker)
            self.applied_lsn = rec.lsn
        return self

    def close(self) -> None:
        self.wal.close()


def rebuild_oracle_store(index: MutableIndex) -> tuple[VectorStore,
                                                       np.ndarray]:
    """The from-scratch oracle the merge must be bit-identical to: a
    capacity-padded store holding base rows then delta rows (garbage
    zeros beyond), plus the packed LIVE mask (allocated ∧ ¬tombstoned) to
    AND into any filter bitmap before `bruteforce.filtered_knn` over the
    whole thing.  Padding rows never score — their mask bit is 0."""
    cap, dim = index.capacity, index.store.dim
    full = np.zeros((cap, dim), np.float32)
    full[:index.base_n] = np.asarray(index.store.vectors)
    n_alloc = index.base_n + index.delta.count
    full[index.base_n:n_alloc] = index.delta.vectors[:index.delta.count]
    alloc = np.zeros(cap, bool)
    alloc[:n_alloc] = True
    alloc[index.tombstones.dead_ids()] = False
    store = VectorStore.build(full, index.metric)
    bits = np.packbits(alloc, bitorder="little")
    pad = (-bits.shape[0]) % 4
    words = np.pad(bits, (0, pad)).view(np.uint32)
    return store, words
