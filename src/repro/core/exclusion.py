"""FAVOR-style selectivity-aware exclusion distances (DESIGN.md §14).

The paper's headline finding is that filtered graph traversal drowns in
per-node filter checks and the heap/index pages they drag in.  FAVOR's
(PAPERS.md) answer is a build-time index of *exclusion distances*: for
every node v, the distance from v to its nearest row that could pass a
predicate of a given selectivity class.  During traversal a candidate v
with exclusion radius e(v) can be dropped without probing the filter or
expanding its neighborhood whenever the radius proves no passing row
reachable "through" v can beat the current result tail.

Two radius sources, both squared-l2 (matching the engine's distance
convention — the triangle inequality is applied in root space):

  * a **ladder** of K-th-NN radii e_K(v) for a static set of K values —
    the selectivity-agnostic tier: for a predicate of selectivity s, the
    nearest passing row is (in expectation, under an uncorrelated
    predicate) about as far as the ceil(1/s)-th NN, so the engine picks
    the ladder rung K ≈ 1/s at query time;
  * **family radii**: for a registered hot predicate family (a concrete
    bitmap shared by many queries), the *exact* distance from every node
    to its nearest passing row.  With exact radii and margin ≥ 1 the
    prune is provably inert (tests assert this); margin < 1 is the
    productive regime.

The index is plain build-time data.  The fused keep-mask itself lives in
`kernels/frontier_scan.py` / `kernels/ref.py` and is threaded through
`core/graph_search.py` (`SearchParams.exclusion="prune"`).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import METRIC_L2, VectorStore, unpack_bitmap

# Default K ladder: geometric so any selectivity in [1/n, 1] is within 2x
# of a rung.  K=1 is the nearest *other* row (self excluded).
DEFAULT_LADDER_KS = (1, 2, 4, 8, 16, 32, 64, 128, 256)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ExclusionIndex:
    """Per-node exclusion radii (squared l2).

    ladder: (R, N) f32, ladder[r, v] = squared distance from v to its
        ladder_ks[r]-th nearest neighbor (self excluded).
    family_radii: (F, N) f32, exact squared distance from v to the
        nearest row passing registered family f (+inf for an empty
        family).  (0, N) when no families are registered.
    family_bitmaps: (F, W) uint32 packed bitmaps of the registered
        families, used for exact-equality matching at plan time.
    """

    ladder: jax.Array
    family_radii: jax.Array
    family_bitmaps: jax.Array
    ladder_ks: tuple[int, ...] = dataclasses.field(
        metadata=dict(static=True), default=DEFAULT_LADDER_KS)
    family_tags: tuple[str, ...] = dataclasses.field(
        metadata=dict(static=True), default=())

    @property
    def n(self) -> int:
        return self.ladder.shape[1]

    @property
    def num_families(self) -> int:
        return len(self.family_tags)


def _blocked_sq_dists(vectors: np.ndarray, norms: np.ndarray,
                      lo: int, hi: int) -> np.ndarray:
    """Squared-l2 rows [lo, hi) vs all rows, (hi-lo, N) f32, self = +inf."""
    block = vectors[lo:hi]
    d = (norms[lo:hi, None] + norms[None, :]
         - 2.0 * block @ vectors.T).astype(np.float32)
    np.maximum(d, 0.0, out=d)
    d[np.arange(hi - lo), np.arange(lo, hi)] = np.inf
    return d


def build_exclusion(store: VectorStore,
                    families: Optional[Mapping[str, np.ndarray]] = None,
                    ladder_ks: Sequence[int] = DEFAULT_LADDER_KS,
                    block: int = 1024) -> ExclusionIndex:
    """Build-time pass: K-th-NN ladder + exact per-family radii.

    families maps tag -> packed (W,) uint32 bitmap of the family's
    passing rows (the same object queries of that family carry).  One
    blocked O(N²/block) sweep computes both tiers.
    """
    if store.metric != METRIC_L2:
        raise ValueError("exclusion radii require metric='l2' "
                         f"(got {store.metric!r})")
    ladder_ks = tuple(int(k) for k in ladder_ks)
    if not ladder_ks or any(k < 1 for k in ladder_ks):
        raise ValueError("ladder_ks must be >= 1")
    n = store.n
    vectors = np.asarray(store.vectors, np.float32)
    norms = np.asarray(store.norms_sq, np.float32)
    families = dict(families or {})
    tags = tuple(sorted(families))
    fam_bits = [unpack_bitmap(np.asarray(families[t]), n) for t in tags]

    ladder = np.empty((len(ladder_ks), n), np.float32)
    fam = np.full((len(tags), n), np.inf, np.float32)
    kmax = min(max(ladder_ks), n - 1) if n > 1 else 0
    for lo in range(0, n, block):
        hi = min(lo + block, n)
        d = _blocked_sq_dists(vectors, norms, lo, hi)
        if kmax > 0:
            # partition pins only index kmax-1; the smaller rungs read
            # inside the partitioned head, so sort that head (kmax <= 256
            # columns — cheap next to the O(n) distance sweep)
            head = np.partition(d, kmax - 1, axis=1)[:, :kmax]
            head.sort(axis=1)
            for r, k in enumerate(ladder_ks):
                kk = min(k, n - 1)
                ladder[r, lo:hi] = head[:, kk - 1]
        else:
            ladder[:, lo:hi] = np.inf
        for f, bits in enumerate(fam_bits):
            if bits.any():
                fam[f, lo:hi] = d[:, bits].min(axis=1)
                # A passing row's own radius is 0 (self-distance was
                # masked to +inf above, but v itself passes).
                row_pass = bits[lo:hi]
                fam[f, lo:hi][row_pass] = 0.0
    words = (n + 31) // 32
    fam_words = (np.stack([np.asarray(families[t]) for t in tags])
                 if tags else np.zeros((0, words), np.uint32))
    return ExclusionIndex(
        ladder=jnp.asarray(ladder),
        family_radii=jnp.asarray(fam),
        family_bitmaps=jnp.asarray(fam_words.astype(np.uint32)),
        ladder_ks=ladder_ks, family_tags=tags)


def ladder_rung(excl: ExclusionIndex, selectivity: float) -> int:
    """Ladder row whose K is nearest (in log space) to 1/selectivity."""
    target = 1.0 / max(float(selectivity), 1e-9)
    ks = np.asarray(excl.ladder_ks, np.float64)
    return int(np.argmin(np.abs(np.log(ks) - np.log(target))))


def match_families(excl: ExclusionIndex, bitmaps) -> np.ndarray:
    """(Q,) int32: index of the registered family whose bitmap equals each
    query's bitmap word-for-word, or -1.  Exact-match only — the JAG /
    family tiers never serve a predicate they were not built for."""
    bm = np.asarray(bitmaps)
    if excl.num_families == 0:
        return np.full(bm.shape[0], -1, np.int32)
    fam = np.asarray(excl.family_bitmaps)
    eq = (bm[:, None, :] == fam[None, :, :]).all(-1)  # (Q, F)
    hit = eq.any(1)
    return np.where(hit, eq.argmax(1), -1).astype(np.int32)


def select_radii(excl: ExclusionIndex, bitmaps,
                 selectivity: Optional[float] = None) -> jax.Array:
    """Per-query (Q, N) exclusion radii: the exact family row where the
    query's bitmap matches a registered family, else the ladder rung for
    K ≈ 1/selectivity (selectivity defaults to the bitmap popcount)."""
    bm = np.asarray(bitmaps)
    q = bm.shape[0]
    if selectivity is None:
        pop = unpack_bitmap(bm, excl.n).sum(-1)
        selectivity = float(np.mean(pop)) / max(excl.n, 1)
    rung = ladder_rung(excl, selectivity)
    out = jnp.broadcast_to(excl.ladder[rung], (q, excl.n))
    fam = match_families(excl, bm)
    if (fam >= 0).any():
        fam_rows = excl.family_radii[jnp.maximum(jnp.asarray(fam), 0)]
        out = jnp.where(jnp.asarray(fam >= 0)[:, None], fam_rows, out)
    return out
