"""Sharded views of the graph + storage tiers (DESIGN.md §13).

The mesh-sharded frontier engine partitions every row-indexed tier — the
full-precision heap, the SQ8 shadow heap, the precomputed norms, and the
base-layer adjacency — by contiguous row range across the devices of a
1-D `shard` mesh axis.  Each device holds one block of `rows_per_shard =
ceil(n / S)` rows (the last block zero/-1 padded) and sees the collection
through the two view dataclasses below, which present the *global*
geometry (`n`, `num_levels`, trace widths, visited-bitset words) while
physically holding only the local block.

The views are consumed by `core.graph_search`, whose gather helpers
dispatch on the view type: a read of global row id g resolves to

    own   = offset <= g < offset + local_n          (exactly one shard)
    value = pmin/pmax over the mesh axis of the owner-masked local read

so in `collective=True` mode every shard observes the bit-exact value the
single-device engine would have read — the reductions select the owner's
untouched f32/int32 payload (non-owners contribute +inf / INT32_MIN),
they never do arithmetic on it.  With `collective=False` the same views
describe the shard's *induced subgraph*: remote reads come back masked
(+inf distances, -1 neighbor ids), which is the traversal mode the
beam-exchange driver runs between exchanges (`core.distributed`).

`offset` is derived from `lax.axis_index` at trace time, so one view
pytree works identically under `jax.vmap(..., axis_name=...)` (the
single-device emulation path) and `shard_map` on a real mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.types import Array

SHARD_AXIS = "shard"


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShardStore:
    """One shard's row-range block of a `VectorStore` (+ SQ8 shadow).

    Data leaves hold the local block ((local_n, d) rows, (local_n,)
    norms); the SQ8 quantizer params (`q_scale`/`q_mean`) are global
    per-dimension vectors, replicated.  Static metadata carries the
    global geometry so `store.n`/`store.dim` keep their single-device
    meaning everywhere the engine sizes bitsets, traces, or budgets.
    """

    # The f32 tier may be absent (None) on SQ8-only stores streamed at a
    # scale where the full-precision heap is never materialized
    # (data.make_dataset_streamed(f32=False)); geometry then derives from
    # the shadow block, and quant="none" traversal / sq8_rerank are
    # invalid by construction (the executor validates).
    vectors: Optional[Array]                # (local_n, d) f32 block
    norms_sq: Optional[Array]               # (local_n,) f32
    metric: str = dataclasses.field(metadata=dict(static=True), default="l2")
    axis: str = dataclasses.field(metadata=dict(static=True),
                                  default=SHARD_AXIS)
    n_total: int = dataclasses.field(metadata=dict(static=True), default=0)
    # collective=True: remote reads resolve over the mesh axis (bit-exact
    # lockstep mode); False: remote reads are masked (induced-subgraph
    # drift mode between beam exchanges).
    collective: bool = dataclasses.field(metadata=dict(static=True),
                                         default=True)
    q_vectors: Optional[Array] = None       # (local_n, d) int8 block
    q_scale: Optional[Array] = None         # (d,) f32, global
    q_mean: Optional[Array] = None          # (d,) f32, global
    q_norms_sq: Optional[Array] = None      # (local_n,) f32

    @property
    def n(self) -> int:
        return self.n_total

    @property
    def dim(self) -> int:
        block = self.vectors if self.vectors is not None else self.q_vectors
        return block.shape[1]

    @property
    def local_n(self) -> int:
        block = self.vectors if self.vectors is not None else self.q_vectors
        return block.shape[0]

    @property
    def has_sq8(self) -> bool:
        return self.q_vectors is not None

    @property
    def offset(self) -> Array:
        """First global row id of this shard's block — derived from the
        mesh position at trace time (valid under vmap-with-axis-name and
        shard_map alike)."""
        return (jax.lax.axis_index(self.axis) * self.local_n).astype(
            jnp.int32)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShardGraph:
    """One shard's row-range block of an `HNSWGraph`.

    `neighbors` is the (num_levels, local_n, deg) adjacency block; values
    are GLOBAL row ids (-1 padded) so a collective read reconstructs the
    single-device row bit-exactly.  `entry_point` is the global entry
    (replicated scalar); `local_entry` is this shard's own highest-level
    node, the seed the drift-mode driver zooms in from so every shard has
    a live entry inside its induced subgraph.
    """

    neighbors: Array                        # (L, local_n, deg) int32
    entry_point: Array                      # () int32, global entry
    local_entry: Array                      # () int32, per-shard entry
    m: int = dataclasses.field(metadata=dict(static=True), default=16)
    axis: str = dataclasses.field(metadata=dict(static=True),
                                  default=SHARD_AXIS)
    n_total: int = dataclasses.field(metadata=dict(static=True), default=0)
    collective: bool = dataclasses.field(metadata=dict(static=True),
                                         default=True)

    @property
    def n(self) -> int:
        return self.n_total

    @property
    def num_levels(self) -> int:
        return self.neighbors.shape[0]

    @property
    def local_n(self) -> int:
        return self.neighbors.shape[1]

    @property
    def offset(self) -> Array:
        return (jax.lax.axis_index(self.axis) * self.local_n).astype(
            jnp.int32)
