"""Filtered ScaNN: clustering-based index (paper §2.3.7, §3.3).

Tree: optional branch level over leaves (the paper's `max_num_levels`), built
with k-means.  Leaves are dense, MXU-aligned int8 (SQ8) tiles — the TPU
analogue of the paper's "leaf packs as many vectors as fit in a page, linked
list of pages" layout.  Optional PCA rotation precedes quantization (paper
Table 5: PCA 1536→193 for OpenAI-5M).

Search (paper Fig. 5/7): ① score branch centroids → top branches,
② score their leaf centroids → top `num_leaves_to_search` leaves,
③ fused filtered leaf scan (Pallas kernel): bitmap probe → dequantized
scoring of passing rows only, ④ reordering: fetch full-precision vectors of
the top k×reorder_factor candidates from the heap, rescore exactly, top-k.

Counters follow Table 6's ScaNN columns: filter checks = every valid row in
every opened leaf; distance comps = rows passing filters; hops = leaves
scanned; reorder_rows = reordering candidates; page accesses = quantized
leaf pages + heap pages for reordering.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import (SearchParams, SearchStats, VectorStore,
                              distance, heap_pages_per_vector,
                              probe_bitmap, sq8_quantize, topk_smallest)
from repro.kernels import ops as kops
from repro.storage.pages import PAGE_BYTES, scann_pages_per_leaf


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ScannIndex:
    # quantized leaf storage (possibly PCA-projected space)
    leaf_tiles: jax.Array      # (L, C, dp) int8
    leaf_rowids: jax.Array     # (L, C) int32, -1 padded
    leaf_centroids: jax.Array  # (L, dp) f32
    scale: jax.Array           # (dp,) f32   dequant: x = tile*scale + mean
    mean: jax.Array            # (dp,) f32
    # optional branch level (ids -1-padded); single-level if B == 0 rows
    branch_centroids: jax.Array  # (B, dp) f32
    branch_leaves: jax.Array     # (B, Lb) int32
    # optional PCA projection from original d to dp
    pca: jax.Array               # (d, dp) f32 (identity-like if disabled)
    # build-time ||x||² of the dequantized rows (L2 fast path; None on
    # indexes built before this field existed — recomputed lazily)
    row_norms_sq: jax.Array | None = None   # (L, C) f32
    metric: str = dataclasses.field(metadata=dict(static=True), default="l2")
    levels: int = dataclasses.field(metadata=dict(static=True), default=2)

    def __getattr__(self, name):
        # indexes pickled before row_norms_sq existed unpickle without the
        # attribute; treat them as "not precomputed"
        if name == "row_norms_sq":
            return None
        raise AttributeError(name)

    @property
    def num_leaves(self) -> int:
        return self.leaf_tiles.shape[0]


def _kmeans(x: np.ndarray, k: int, iters: int = 12, seed: int = 0,
            block: int = 8192) -> tuple[np.ndarray, np.ndarray]:
    """Plain Lloyd's. Returns (centroids (k, d), assignment (n,))."""
    n = x.shape[0]
    rng = np.random.RandomState(seed)
    cent = x[rng.choice(n, size=k, replace=False)].copy()
    assign = np.zeros(n, np.int64)
    for _ in range(iters):
        for s in range(0, n, block):
            e = min(s + block, n)
            d = ((x[s:e] ** 2).sum(1)[:, None] + (cent ** 2).sum(1)[None, :]
                 - 2.0 * x[s:e] @ cent.T)
            assign[s:e] = d.argmin(1)
        sums = np.zeros_like(cent)
        np.add.at(sums, assign, x)
        cnt = np.bincount(assign, minlength=k).astype(np.float64)
        empty = cnt == 0
        cent = np.where(empty[:, None], cent,
                        sums / np.maximum(cnt, 1)[:, None])
        if empty.any():  # reseed empty clusters on far points
            far = rng.choice(n, size=int(empty.sum()), replace=False)
            cent[empty] = x[far]
    return cent.astype(np.float32), assign


def build_scann(store: VectorStore, num_leaves: int, levels: int = 2,
                pca_dims: int | None = None, seed: int = 0,
                kmeans_iters: int = 12) -> ScannIndex:
    x = np.asarray(store.vectors, np.float32)
    n, d = x.shape

    if pca_dims is not None and pca_dims < d:
        mu = x.mean(0)
        xc = x - mu
        cov = (xc.T @ xc) / max(n - 1, 1)
        w, v = np.linalg.eigh(cov)
        proj = v[:, ::-1][:, :pca_dims].astype(np.float32)
        # fold the centering into the projection space: xp = (x - mu) @ proj
        xp = xc @ proj
        pca = proj
        pca_mu = mu
    else:
        xp = x
        pca = np.eye(d, dtype=np.float32)
        pca_mu = np.zeros(d, np.float32)
    dp = xp.shape[1]

    cent, assign = _kmeans(xp, num_leaves, iters=kmeans_iters, seed=seed)
    counts = np.bincount(assign, minlength=num_leaves)
    cap = int(counts.max())
    cap += (-cap) % 8  # sublane alignment
    rowids = np.full((num_leaves, cap), -1, np.int64)
    order = np.argsort(assign, kind="stable")
    offs = np.zeros(num_leaves, np.int64)
    for row in order:
        a = assign[row]
        rowids[a, offs[a]] = row
        offs[a] += 1

    # SQ8: per-dimension affine quantization over the dataset (the shared
    # quantizer — the graph engine's shadow store uses the same one)
    q, scale, mean = sq8_quantize(xp)
    tiles = np.zeros((num_leaves, cap, dp), np.int8)
    valid = rowids >= 0
    tiles[valid] = q[rowids[valid]]

    if levels >= 2 and num_leaves >= 16:
        nb = max(4, int(np.sqrt(num_leaves)))
        bcent, bassign = _kmeans(cent, nb, iters=kmeans_iters, seed=seed + 1)
        lb = int(np.bincount(bassign, minlength=nb).max())
        bleaves = np.full((nb, lb), -1, np.int64)
        boffs = np.zeros(nb, np.int64)
        for leaf in np.argsort(bassign, kind="stable"):
            b = bassign[leaf]
            bleaves[b, boffs[b]] = leaf
            boffs[b] += 1
    else:
        levels = 1
        bcent = np.zeros((1, dp), np.float32)
        bleaves = np.arange(num_leaves, dtype=np.int64)[None, :]

    # store the PCA mean by folding it into `mean` of the quantizer space:
    # query projection must also subtract pca_mu — stash it in pca row space
    # by augmenting: qp = (q - pca_mu) @ pca. We keep pca_mu separately:
    tiles_j = jnp.asarray(tiles)
    scale_j, mean_j = jnp.asarray(scale), jnp.asarray(mean)
    idx = ScannIndex(
        leaf_tiles=tiles_j,
        leaf_rowids=jnp.asarray(rowids, jnp.int32),
        leaf_centroids=jnp.asarray(cent),
        scale=scale_j, mean=mean_j,
        branch_centroids=jnp.asarray(bcent),
        branch_leaves=jnp.asarray(bleaves, jnp.int32),
        pca=jnp.asarray(np.concatenate([pca, pca_mu[None, :] @ pca], 0)),
        row_norms_sq=_row_norms_sq(tiles_j, scale_j, mean_j),
        metric=store.metric, levels=levels)
    return idx


@jax.jit
def _row_norms_sq(tiles: jax.Array, scale: jax.Array,
                  mean: jax.Array) -> jax.Array:
    """||x||² of every dequantized leaf row, (L, C) f32 — same dequant +
    reduction the kernels apply, so precomputed and inline norms agree."""
    x = tiles.astype(jnp.float32) * scale + mean
    return jnp.sum(x * x, axis=-1)


def project_query(index: ScannIndex, q: jax.Array) -> jax.Array:
    """Apply the (folded-centering) PCA projection to a query."""
    proj, mu_p = index.pca[:-1], index.pca[-1]
    return q @ proj - mu_p


def _quant_pages_per_leaf(index: ScannIndex) -> int:
    # geometry owned by the storage layer (storage/pages.py, DESIGN.md §8)
    return scann_pages_per_leaf(index.leaf_tiles.shape[1],
                                index.leaf_tiles.shape[2])


_heap_pages_per_vector = heap_pages_per_vector  # shared formula (types.py)


def leaves_within_budget(index: ScannIndex, store: VectorStore,
                         params: SearchParams) -> tuple[int, bool]:
    """Plan-time anytime clamp (DESIGN.md §10): the largest
    `num_leaves_to_search` whose worst-case per-query cost fits the
    budgets in `params` — ScaNN's leaf count is a static shape, so its
    budget enforcement happens at planning, not inside the kernels.

    Returns (nl, clamped).  Never returns less than one leaf: the last
    leaf always scans and the caller flags the query budget_exhausted
    instead (ScannExecutor threads `clamped` into AnytimeInfo).
    """
    from repro.core.costmodel import budget_cycle_weights
    L, C, _ = index.leaf_tiles.shape
    nl0 = min(params.num_leaves_to_search, L)
    if params.page_budget <= 0 and params.hop_budget <= 0 \
            and params.deadline_cycles <= 0:
        return nl0, False
    qppl = _quant_pages_per_leaf(index)
    ppv = _heap_pages_per_vector(store.dim)
    cent = L + (index.branch_centroids.shape[0] if index.levels >= 2 else 0)
    w = budget_cycle_weights(store.dim)
    for nl in range(nl0, 0, -1):
        r = min(params.k * params.reorder_factor, nl * C)
        ok = True
        if params.hop_budget > 0:
            ok = nl <= params.hop_budget
        if ok and params.page_budget > 0:
            ok = nl * qppl + r * ppv <= params.page_budget
        if ok and params.deadline_cycles > 0:
            rows = nl * C
            cyc = (rows + cent + r) * w["distance_comps"] \
                + rows * w["filter_checks"] \
                + nl * qppl * w["page_accesses_index"] \
                + r * ppv * w["page_accesses_heap"] \
                + r * w["reorder_rows"]
            ok = cyc <= params.deadline_cycles
        if ok:
            return nl, nl < nl0
    return 1, nl0 > 1


def _search_single(index: ScannIndex, store: VectorStore, q, bitmap,
                   params: SearchParams, use_pallas: bool):
    qp = project_query(index, q)
    L, C, dp = index.leaf_tiles.shape
    nl = min(params.num_leaves_to_search, L)
    stats = SearchStats.zeros()

    if index.levels >= 2:
        B, Lb = index.branch_leaves.shape
        bd = distance(index.metric, qp[None], index.branch_centroids,
                      jnp.sum(index.branch_centroids ** 2, -1))
        # open enough branches to cover nl leaves (paper Fig. 5-①)
        nb = min(B, max(1, -(-nl * 2 * B // L)))
        _, bsel = topk_smallest(bd, nb)
        cand_leaves = index.branch_leaves[bsel].reshape(-1)      # (nb*Lb,)
        cl = jnp.maximum(cand_leaves, 0)
        ld = distance(index.metric, qp[None], index.leaf_centroids[cl],
                      jnp.sum(index.leaf_centroids[cl] ** 2, -1))
        ld = jnp.where(cand_leaves >= 0, ld, jnp.inf)
        _, pos = topk_smallest(ld, nl)
        leaves = cl[pos]                                          # (nl,)
        cent_scored = index.branch_centroids.shape[0] + cand_leaves.shape[0]
    else:
        ld = distance(index.metric, qp[None], index.leaf_centroids,
                      jnp.sum(index.leaf_centroids ** 2, -1))
        _, leaves = topk_smallest(ld, nl)
        cent_scored = L

    tiles = index.leaf_tiles[leaves]          # (nl, C, dp)
    rowids = index.leaf_rowids[leaves]        # (nl, C)
    scores = kops.leaf_scan(qp, tiles, rowids, index.scale, index.mean,
                            bitmap, metric=index.metric,
                            use_pallas=use_pallas)                # (nl, C)

    valid = rowids >= 0
    n_valid = valid.sum()
    passing = jnp.isfinite(scores)
    n_pass = passing.sum()

    # candidate selection + full-precision reordering (paper §6.2.2)
    r = min(params.k * params.reorder_factor, nl * C)
    flat_s, flat_pos = topk_smallest(scores.reshape(-1), r)
    cand_rows = rowids.reshape(-1)[flat_pos]
    cand_ok = jnp.isfinite(flat_s) & (cand_rows >= 0)
    exact = distance(store.metric, q[None], store.vectors[
        jnp.maximum(cand_rows, 0)], store.norms_sq[jnp.maximum(cand_rows, 0)])
    exact = jnp.where(cand_ok, exact, jnp.inf)
    dk, pos = topk_smallest(exact, params.k)
    ids = jnp.where(jnp.isinf(dk), -1, cand_rows[pos])

    n_reorder = cand_ok.sum()
    stats = SearchStats(
        distance_comps=stats.distance_comps + n_pass + cent_scored + n_reorder,
        filter_checks=stats.filter_checks + n_valid,
        hops=stats.hops + nl,
        page_accesses_index=stats.page_accesses_index
        + nl * _quant_pages_per_leaf(index),
        page_accesses_heap=stats.page_accesses_heap
        + n_reorder * _heap_pages_per_vector(store.dim),
        tmap_lookups=stats.tmap_lookups,
        reorder_rows=stats.reorder_rows + n_reorder)
    return dk, ids, stats


@partial(jax.jit, static_argnames=("params", "use_pallas"))
def scann_search_batch_vmapped(index: ScannIndex, store: VectorStore,
                               queries, bitmaps, params: SearchParams,
                               use_pallas: bool = False):
    """Legacy per-query path: vmap of the single-query search.  Every leaf
    tile is re-fetched and re-scored once per query — kept as the
    equivalence oracle and microbenchmark baseline for the batched
    pipeline below."""
    return jax.vmap(lambda q, b: _search_single(
        index, store, q, b, params, use_pallas))(queries, bitmaps)


def _unique_pad(ids: jax.Array, domain: int, cap: int):
    """Static-shape set union: distinct values of `ids` (all in
    [0, domain)), padded to `cap` entries.  Returns (members (cap,) int32,
    valid (cap,) bool, inv (domain,) int32) with inv[members[i]] == i for
    valid slots.  Order: ascending id, members first (lax.top_k tie-break
    is lowest-index-first)."""
    present = jnp.zeros((domain,), jnp.int32).at[ids].set(1)
    pv, members = jax.lax.top_k(present, cap)
    valid = pv > 0
    inv = jnp.zeros((domain,), jnp.int32).at[members].set(
        jnp.arange(cap, dtype=jnp.int32))
    return members.astype(jnp.int32), valid, inv


def _select_leaves(index: ScannIndex, qp: jax.Array, nl: int,
                   use_pallas: bool):
    """Stage ①/② of Fig. 5, batched: one distance_matrix call per centroid
    level instead of per-query loops.  Returns (leaves (Q, nl), cent_scored
    per query)."""
    L = index.leaf_tiles.shape[0]
    if index.levels >= 2:
        B, Lb = index.branch_leaves.shape
        bd = kops.distance_matrix(qp, index.branch_centroids,
                                  metric=index.metric,
                                  use_pallas=use_pallas)          # (Q, B)
        nb = min(B, max(1, -(-nl * 2 * B // L)))
        _, bsel = topk_smallest(bd, nb)                           # (Q, nb)
        cand = index.branch_leaves[bsel].reshape(qp.shape[0], -1)  # (Q, nb*Lb)
        cl = jnp.maximum(cand, 0)
        ldf = kops.distance_matrix(qp, index.leaf_centroids,
                                   metric=index.metric,
                                   use_pallas=use_pallas)         # (Q, L)
        ld = jnp.where(cand >= 0, jnp.take_along_axis(ldf, cl, 1), jnp.inf)
        _, pos = topk_smallest(ld, nl)
        leaves = jnp.take_along_axis(cl, pos, 1)                  # (Q, nl)
        return leaves, B + cand.shape[1]
    ld = kops.distance_matrix(qp, index.leaf_centroids,
                              metric=index.metric, use_pallas=use_pallas)
    _, leaves = topk_smallest(ld, nl)
    return leaves, L


@partial(jax.jit, static_argnames=("params", "use_pallas", "collect_trace"))
def scann_search_batch(index: ScannIndex, store: VectorStore, queries,
                       bitmaps, params: SearchParams,
                       use_pallas: bool = False,
                       collect_trace: bool = False):
    """Filtered ScaNN search, query-batched (DESIGN.md §4).

    The whole batch moves through each stage together: ① one
    distance_matrix call per centroid level, ② the union of opened leaves
    is scanned ONCE by the batched fused kernel (MXU (Q, d) × (d, C)
    contraction per tile, per-query bitmap probes), ③ per-query candidate
    selection over the gathered scores, ④ the union of reordering
    candidates is gathered full-precision once and each query rescores its
    own r candidates in one batched contraction.  Counters
    keep Table 6 semantics; index-page accounting follows
    params.scann_page_accounting (DESIGN.md §5).

    `params.scann_query_block` > 0 tiles the query batch: each tile of B
    queries runs the full pipeline over its own leaf union, so the
    (Q, U, C) union-scan block — which grows ~quadratically with batch
    size when query leaf sets are disjoint — stays VMEM/HBM-bounded
    (DESIGN.md §4 "Scaling envelope").  ids/dists are tile-size-invariant
    (each query only ever reads its own leaves' scores); "batch"
    index-page accounting amortizes per tile instead of per batch.

    `collect_trace=True` additionally returns the storage-access trace
    (DESIGN.md §8) as a 4th element: `{"leaves": (Q, nl) leaves opened in
    rank order, "cand_rows": (Q, r) reorder heap rows in candidate order,
    "cand_ok": (Q, r) validity}` — exactly the object touches the page
    counters charge, for the buffer pool to replay.  ids/dists/stats are
    identical with the flag on or off."""
    if index.metric not in ("l2", "ip") or store.metric not in ("l2", "ip"):
        # distance_matrix (and the leaf-scan kernels) only implement L2/IP;
        # fail loudly instead of silently ranking cos stores by L2
        raise NotImplementedError(
            f"batched ScaNN pipeline supports 'l2'/'ip' metrics, got "
            f"index={index.metric!r} store={store.metric!r}; use "
            f"scann_search_batch_vmapped for other metrics")
    Q = queries.shape[0]
    B = params.scann_query_block
    if B < 0:
        raise ValueError(f"scann_query_block must be >= 0, got {B}")
    if 0 < B < Q:
        outs = [_scann_search_block(index, store, queries[s:s + B],
                                    bitmaps[s:s + B], params, use_pallas,
                                    collect_trace)
                for s in range(0, Q, B)]
        dk = jnp.concatenate([o[0] for o in outs])
        ids = jnp.concatenate([o[1] for o in outs])
        stats = jax.tree.map(lambda *xs: jnp.concatenate(xs),
                             *[o[2] for o in outs])
        if collect_trace:
            trace = {k: jnp.concatenate([o[3][k] for o in outs])
                     for k in outs[0][3]}
            return dk, ids, stats, trace
        return dk, ids, stats
    return _scann_search_block(index, store, queries, bitmaps, params,
                               use_pallas, collect_trace)


def _scann_search_block(index: ScannIndex, store: VectorStore, queries,
                        bitmaps, params: SearchParams, use_pallas: bool,
                        collect_trace: bool = False):
    """One query tile through the batched pipeline (stages ①–④ above)."""
    Q = queries.shape[0]
    L, C, dp = index.leaf_tiles.shape
    nl = min(params.num_leaves_to_search, L)
    qp = project_query(index, queries)                            # (Q, dp)

    leaves, cent_scored = _select_leaves(index, qp, nl, use_pallas)

    # ② union of opened leaves — each tile fetched/scored once per batch
    cap = min(L, Q * nl)
    uleaves, uvalid, inv = _unique_pad(leaves.reshape(-1), L, cap)
    tiles = index.leaf_tiles[uleaves]                             # (U, C, dp)
    rowids_u = jnp.where(uvalid[:, None], index.leaf_rowids[uleaves], -1)
    if index.metric == "ip":
        norms_u = jnp.zeros((cap, C), jnp.float32)                # unused
    elif index.row_norms_sq is not None:
        norms_u = index.row_norms_sq[uleaves]
    else:
        norms_u = _row_norms_sq(tiles, index.scale, index.mean)
    scores_u = kops.leaf_scan_batched(qp, tiles, rowids_u, index.scale,
                                      index.mean, bitmaps, norms_u,
                                      metric=index.metric,
                                      use_pallas=use_pallas)      # (Q, U, C)

    # gather each query's opened leaves back out of the union scan
    pos_in_u = inv[leaves]                                        # (Q, nl)
    scores = jnp.take_along_axis(scores_u, pos_in_u[:, :, None], 1)
    rowids = rowids_u[pos_in_u]                                   # (Q, nl, C)

    valid = rowids >= 0
    n_valid = valid.sum(axis=(1, 2))                              # (Q,)
    n_pass = jnp.isfinite(scores).sum(axis=(1, 2))

    # ③ per-query candidate selection (paper §6.2.2)
    r = min(params.k * params.reorder_factor, nl * C)
    flat_s, flat_pos = topk_smallest(scores.reshape(Q, -1), r)
    cand_rows = jnp.take_along_axis(rowids.reshape(Q, -1), flat_pos, 1)
    cand_ok = jnp.isfinite(flat_s) & (cand_rows >= 0)

    # ④ full-precision reordering: the union of candidate heap rows is
    # gathered from the store ONCE (the shared-fetch amortization), then
    # each query rescores only its own r candidates out of the fetched
    # block — one batched (Q, r, d) contraction at the legacy FLOP count,
    # not Q × |union| distances.  Dedup via sort + searchsorted —
    # O(Q·r log Q·r), independent of store.n.
    safe_rows = jnp.maximum(cand_rows, 0)
    rcap = min(store.n, Q * r)
    flat = safe_rows.reshape(-1)
    srt = jnp.sort(flat)
    is_new = jnp.concatenate([jnp.ones((1,), bool), srt[1:] != srt[:-1]])
    uslot = jnp.cumsum(is_new) - 1              # unique slot of each sorted id
    urows = jnp.zeros((rcap,), jnp.int32).at[uslot].set(srt)
    rows_u = store.vectors[urows]                                 # (rcap, d)
    norms_u2 = store.norms_sq[urows]
    pos = uslot[jnp.searchsorted(srt, flat)].reshape(Q, r)
    exact = distance(store.metric, queries[:, None, :],
                     rows_u[pos], norms_u2[pos])                  # (Q, r)
    exact = jnp.where(cand_ok, exact, jnp.inf)
    dk, pos = topk_smallest(exact, params.k)
    ids = jnp.where(jnp.isinf(dk),
                    -1, jnp.take_along_axis(cand_rows, pos, 1))
    n_reorder = cand_ok.sum(axis=1)

    # counters (Table 6 semantics, per query)
    qppl = _quant_pages_per_leaf(index)
    if params.scann_page_accounting not in ("batch", "per_query"):
        raise ValueError(
            f"scann_page_accounting must be 'batch' or 'per_query', got "
            f"{params.scann_page_accounting!r}")
    if params.scann_page_accounting == "per_query":
        idx_pages = jnp.full((Q,), nl * qppl, jnp.int32)
    else:
        # batch accounting: each opened leaf page is charged once per
        # batch, to the first query that opened it (DESIGN.md §5)
        opened = jnp.zeros((Q, cap), bool).at[
            jnp.arange(Q)[:, None], pos_in_u].set(True)
        first = jnp.argmax(opened, axis=0)                        # (cap,)
        idx_pages = jnp.sum(
            uvalid[None, :] & (first[None, :] == jnp.arange(Q)[:, None]),
            axis=1).astype(jnp.int32) * qppl
    z = jnp.zeros((Q,), jnp.int32)
    stats = SearchStats(
        distance_comps=(n_pass + cent_scored + n_reorder).astype(jnp.int32),
        filter_checks=n_valid.astype(jnp.int32),
        hops=z + nl,
        page_accesses_index=idx_pages,
        page_accesses_heap=(n_reorder
                            * _heap_pages_per_vector(store.dim)).astype(
                                jnp.int32),
        tmap_lookups=z,
        reorder_rows=n_reorder.astype(jnp.int32))
    if collect_trace:
        trace = {"leaves": leaves.astype(jnp.int32),
                 "cand_rows": cand_rows.astype(jnp.int32),
                 "cand_ok": cand_ok}
        return dk, ids, stats, trace
    return dk, ids, stats
