"""Unified executor/planner layer — every search strategy behind one API.

The paper's central finding is that the best filter-agnostic strategy is a
*system-aware decision* (Fig. 1 crossover, §6.2): it flips with
selectivity, vector-predicate correlation, and the per-architecture access
costs.  The repo's strategies historically lived behind three divergent
entry points (`graph_search.search_batch`, `scann.scann_search_batch[...]`,
`bruteforce.filtered_knn`) with three return conventions; this module
collapses them into one protocol so callers — benchmarks, serving, launch —
never hard-code an index again:

    Executor.plan(queries, bitmaps, params)  -> SearchPlan
    Executor.execute(plan)                   -> SearchResult
    Executor.search(queries, bitmaps, params) = execute(plan(...))

Fixed executors (`GraphExecutor`, `ScannExecutor`, `BruteForceExecutor`)
are thin, *bit-identical* ports of the legacy entry points — same jitted
kernels, same SearchStats counters (equivalence-tested in
tests/test_executor.py).  `AdaptivePlanner` is where the paper's finding
becomes machinery: per query batch it estimates selectivity from bitmap
popcounts, estimates correlation from the bitmap density inside the
query's nearest ScaNN leaves, runs `costmodel.predict_cycles` for every
registered candidate, and dispatches to the cheapest recall-feasible one
(decision boundaries in DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
import zlib
from functools import partial
from typing import Any, Mapping, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel
from repro.core.bruteforce import filtered_knn, filtered_knn_partial
from repro.core.exclusion import ExclusionIndex, match_families, select_radii
from repro.core.graph_search import (FrontierState, frontier_finalize,
                                     frontier_idle, frontier_init,
                                     frontier_write_slot, search_batch,
                                     step_supersteps)
from repro.core.hnsw import HNSWGraph, PartitionedGraph
from repro.core.scann import (ScannIndex, _quant_pages_per_leaf,
                              leaves_within_budget, project_query,
                              scann_search_batch,
                              scann_search_batch_vmapped)
from repro.core.types import (SearchParams, SearchResult, SearchStats,
                              VectorStore, distance, heap_pages_per_vector,
                              pack_bool_bitmap, probe_bitmap, quantize_store,
                              topk_smallest)
from repro.storage.engine import (StorageEngine, TRACE_UNTOUCHED,
                                  merge_storage_stats)

GRAPH_STRATEGIES = costmodel.GRAPH_STRATEGIES


@dataclasses.dataclass(frozen=True)
class SearchPlan:
    """What an executor decided to run for one query batch."""

    strategy: str                  # resolved strategy name
    params: SearchParams           # resolved knobs (strategy field set)
    queries: Any                   # (Q, d)
    bitmaps: Any                   # (Q, words) uint32
    # Planner annotations (None for fixed executors):
    est_selectivity: Optional[np.ndarray] = None    # (Q,) popcount/n
    correlation_proxy: Optional[float] = None       # local/global density
    predicted_cycles: Optional[Mapping[str, float]] = None
    # Plan-level adjustments (DESIGN.md §10), e.g. a budget-driven ScaNN
    # leaf clamp or a bruteforce partial-scan row cap — surfaced so the
    # executor can flag the affected queries budget_exhausted.
    notes: Any = None


@runtime_checkable
class Executor(Protocol):
    """Anything that can plan and execute filtered top-k search."""

    name: str
    store: VectorStore

    def plan(self, queries, bitmaps, params: SearchParams) -> SearchPlan: ...

    def execute(self, plan: SearchPlan) -> SearchResult: ...

    def search(self, queries, bitmaps,
               params: SearchParams) -> SearchResult: ...


class BaseExecutor:
    """plan/execute split with the one-call convenience wrapper."""

    name: str = "base"

    def search(self, queries, bitmaps, params: SearchParams) -> SearchResult:
        return self.execute(self.plan(queries, bitmaps, params))

    def plan(self, queries, bitmaps, params: SearchParams) -> SearchPlan:
        raise NotImplementedError

    def execute(self, plan: SearchPlan) -> SearchResult:
        raise NotImplementedError


class GraphExecutor(BaseExecutor):
    """All five graph strategies (paper §2.3) behind the executor API.

    Bit-identical port of `graph_search.search_batch` — the same jitted
    vmapped beam search runs underneath.  With a `storage` engine
    attached, the frontier engine's deduplicated union fetches are
    replayed through the buffer pool (DESIGN.md §8): the search runs with
    trace collection on (ids/dists/stats unchanged) and the result
    carries measured StorageStats."""

    def __init__(self, graph: HNSWGraph, store: VectorStore,
                 strategy: str = "sweeping", use_pallas: bool = False,
                 storage: Optional[StorageEngine] = None,
                 graph_quant: str = "none",
                 exclusion: Optional[ExclusionIndex] = None):
        if strategy not in GRAPH_STRATEGIES:
            raise ValueError(f"unknown graph strategy {strategy!r}")
        if graph_quant not in ("none", "sq8"):
            raise ValueError(f"unknown graph_quant {graph_quant!r}")
        if storage is not None and storage.graph is None:
            raise ValueError("storage engine lacks a graph adjacency "
                             "layout; build it with graph=")
        if graph_quant == "sq8":
            if store.q_vectors is None:
                raise ValueError("graph_quant='sq8' needs a quantize_store'd"
                                 " VectorStore (SQ8 shadow missing)")
            if storage is not None and storage.qheap is None:
                raise ValueError("storage engine lacks the qheap (SQ8 "
                                 "shadow) segment; build it from the "
                                 "quantized store")
        if exclusion is not None:
            # FAVOR pruned traversal (DESIGN.md §14): the keep rule is a
            # triangle-inequality argument in l2 root space, composed
            # with the sweeping engine's W-tail threshold — no other
            # strategy/metric carries the proof.
            if strategy != "sweeping":
                raise ValueError("exclusion pruning only composes with the "
                                 "sweeping strategy")
            if store.metric != "l2":
                raise ValueError("exclusion pruning needs metric='l2'")
            if exclusion.n != store.n:
                raise ValueError(
                    f"exclusion index built over n={exclusion.n} rows but "
                    f"store has n={store.n} (stale radii)")
        self.graph = graph
        self.store = store
        self.strategy = strategy
        self.use_pallas = use_pallas
        self.storage = storage
        self.graph_quant = graph_quant
        self.exclusion = exclusion
        base = strategy if exclusion is None else f"{strategy}_excl"
        self.name = base if graph_quant == "none" \
            else f"{base}_{graph_quant}"

    def resolve_params(self, params: SearchParams) -> SearchParams:
        """Plan-time strategy/quant coercion as a reusable helper.

        External steppers (serving/continuous.py) must resolve params
        exactly the way `plan` does — the resolved object is the jit
        cache key, so resolving differently would compile a second
        stepper for the same logical plan."""
        if params.strategy != self.strategy or \
                params.graph_quant != self.graph_quant:
            params = dataclasses.replace(params, strategy=self.strategy,
                                         graph_quant=self.graph_quant)
        if self.exclusion is None and params.exclusion != "none":
            # an exclusion mode only means something on an executor that
            # owns radii — coerce back so the legacy path stays inert
            params = dataclasses.replace(params, exclusion="none")
        return params

    def plan(self, queries, bitmaps, params: SearchParams) -> SearchPlan:
        params = self.resolve_params(params)
        notes = None
        if self.exclusion is not None:
            # Per-batch radii selection (DESIGN.md §14): family-exact rows
            # where the whole batch hits registered families — that is the
            # regime where "prune_exact" (FAVOR's eliminated filter probe)
            # is sound, because a family radius is 0 iff the row passes.
            # Any non-matching query demotes the batch to the ladder rungs
            # with full fc charging ("prune").
            fam = np.asarray(match_families(self.exclusion, bitmaps))
            mode = "prune_exact" if fam.size and (fam >= 0).all() \
                else "prune"
            params = dataclasses.replace(params, exclusion=mode)
            notes = {"excl": select_radii(self.exclusion, bitmaps)}
        return SearchPlan(self.strategy, params, queries, bitmaps,
                          notes=notes)

    # ---- stepped frontier driver (DESIGN.md §11) --------------------
    # Thin delegates so the continuous-batching scheduler never imports
    # graph_search directly; trace collection follows the storage
    # attachment the same way `execute` does.

    def _no_stepped_exclusion(self):
        if self.exclusion is not None:
            raise ValueError("exclusion pruning is not supported by the "
                             "stepped frontier driver (radii don't ride in "
                             "FrontierState); use the one-shot search path")

    def idle_frontier(self, params: SearchParams, width: int
                      ) -> FrontierState:
        self._no_stepped_exclusion()
        return frontier_idle(self.graph, self.store,
                             self.resolve_params(params), width,
                             collect_trace=self.storage is not None)

    def init_frontier(self, queries, bitmaps, params: SearchParams,
                      deadlines=None) -> FrontierState:
        self._no_stepped_exclusion()
        return frontier_init(self.graph, self.store, queries, bitmaps,
                             self.resolve_params(params),
                             collect_trace=self.storage is not None,
                             deadlines=deadlines)

    def write_frontier_slot(self, state: FrontierState,
                            lane: FrontierState, slot: int) -> FrontierState:
        return frontier_write_slot(state, lane, slot)

    def step_frontier(self, state: FrontierState, params: SearchParams,
                      n_hops: int, dynamic_deadline: bool = False
                      ) -> FrontierState:
        return step_supersteps(self.graph, self.store, state,
                               self.resolve_params(params), n_hops,
                               use_pallas=self.use_pallas,
                               dynamic_deadline=dynamic_deadline)

    def finalize_frontier(self, state: FrontierState,
                          params: SearchParams):
        return frontier_finalize(self.graph, self.store, state,
                                 self.resolve_params(params))

    def execute(self, plan: SearchPlan) -> SearchResult:
        excl = None if plan.notes is None else plan.notes.get("excl")
        if self.storage is None:
            d, ids, stats = search_batch(self.graph, self.store,
                                         plan.queries, plan.bitmaps,
                                         plan.params,
                                         use_pallas=self.use_pallas,
                                         excl=excl)
            return SearchResult(dists=d, ids=ids, stats=stats,
                                strategy=self.strategy, plan=plan,
                                anytime=costmodel.evaluate_anytime(
                                    stats, plan.params, self.store.dim, ids,
                                    hop_cap=plan.params.max_hops))
        if plan.params.graph_exec_mode != "frontier":
            raise ValueError("storage accounting needs the frontier "
                             "engine (graph_exec_mode='frontier')")
        d, ids, stats, trace = search_batch(
            self.graph, self.store, plan.queries, plan.bitmaps, plan.params,
            use_pallas=self.use_pallas, collect_trace=True, excl=excl)
        rr = trace.get("rerank_rows")
        sstats = self.storage.account_graph(
            np.asarray(trace["heap_steps"]),
            np.asarray(trace["index_steps"]),
            rerank_rows=None if rr is None else np.asarray(rr),
            quant=self.graph_quant == "sq8")
        return SearchResult(dists=d, ids=ids, stats=stats,
                            strategy=self.strategy, plan=plan,
                            storage=sstats,
                            anytime=costmodel.evaluate_anytime(
                                stats, plan.params, self.store.dim, ids,
                                hop_cap=plan.params.max_hops))


def _allpass_bitmap(n: int) -> jax.Array:
    """(W,) uint32 bitmap passing exactly rows [0, n)."""
    return jnp.asarray(pack_bool_bitmap(np.ones(n, bool)))


def _scatter_storage_stats(stats, qsel: np.ndarray, q: int):
    """Widen a query-subset StorageStats to the full batch: per-query
    arrays scatter to their global slots (zeros/False elsewhere) so
    `merge_storage_stats` can sum same-shaped parts."""
    def scatter(arr, fill):
        full = np.full(q, fill, np.asarray(arr).dtype)
        full[qsel] = np.asarray(arr)
        return full

    return dataclasses.replace(
        stats,
        index_pages=scatter(stats.index_pages, 0),
        heap_pages=scatter(stats.heap_pages, 0),
        faulted=(None if stats.faulted is None
                 else scatter(stats.faulted, False)))


class PartitionedGraphExecutor(BaseExecutor):
    """JAG-style attribute-partitioned graphs (DESIGN.md §14) behind the
    executor API.

    Each registered predicate *family* owns a private subgraph built over
    exactly its passing rows (`hnsw.build_graph_partitioned`).  A query
    whose bitmap equals a family bitmap word-for-word runs UNFILTERED on
    that subgraph — the filter is the partition, so per-candidate filter
    checks vanish (the JAG claim); the only fc charged is the plan-time
    family match (F·words word comparisons per query).  Queries matching
    no family fall back to the wrapped base executor on the full graph;
    a store grown past `built_n` (stale partitions) demotes the whole
    batch to the fallback.

    With a `storage` engine attached, matched queries' subgraph traces
    are scattered back to GLOBAL row ids and replayed through the base
    heap/adjacency layout — exact for heap pages (same rows, same pages),
    conservative for index pages (a family's private adjacency is packed
    denser than the base layout it is charged through)."""

    def __init__(self, partitions: PartitionedGraph, store: VectorStore,
                 base: Optional[Executor] = None, use_pallas: bool = False,
                 storage: Optional[StorageEngine] = None,
                 graph_quant: str = "none"):
        if graph_quant not in ("none", "sq8"):
            raise ValueError(f"unknown graph_quant {graph_quant!r}")
        if not partitions.partitions:
            raise ValueError("PartitionedGraph holds no partitions")
        if graph_quant == "sq8" and any(
                p.store.q_vectors is None for p in partitions.partitions):
            raise ValueError("graph_quant='sq8' needs partitions built from "
                             "a quantize_store'd VectorStore (SQ8 shadow "
                             "missing in a partition)")
        if storage is not None and storage.graph is None:
            raise ValueError("storage engine lacks a graph adjacency "
                             "layout; build it with graph=")
        self.partitions = partitions
        self.store = store
        self.base = base
        self.use_pallas = use_pallas
        self.storage = storage
        self.graph_quant = graph_quant
        self.strategy = "partitioned"
        self.name = "partitioned" if graph_quant == "none" \
            else f"partitioned_{graph_quant}"

    def plan(self, queries, bitmaps, params: SearchParams) -> SearchPlan:
        stale = self.partitions.built_n != self.store.n
        match = np.full(int(queries.shape[0]), -1, np.int32) if stale \
            else np.asarray(self.partitions.match(bitmaps))
        # the sub-searches run the unfiltered strategy: the partition IS
        # the filter, so traversal gating and the final check both drop
        sub = dataclasses.replace(params, strategy="unfiltered",
                                  graph_quant=self.graph_quant,
                                  exclusion="none")
        return SearchPlan("partitioned", sub, queries, bitmaps,
                          notes={"match": match, "caller_params": params})

    def execute(self, plan: SearchPlan) -> SearchResult:
        match = plan.notes["match"]
        q, k = int(plan.queries.shape[0]), plan.params.k
        unmatched = np.flatnonzero(match < 0)
        if unmatched.size and self.base is None:
            raise ValueError(
                f"{unmatched.size} queries match no partition family and "
                "no base executor is attached for fallback")
        dists = np.full((q, k), np.inf, np.float32)
        ids = np.full((q, k), -1, np.int32)
        counters = {f.name: np.zeros(q, np.int32)
                    for f in dataclasses.fields(SearchStats)}
        sparts = []
        tracing = self.storage is not None
        for f_idx in np.unique(match[match >= 0]):
            part = self.partitions.partitions[int(f_idx)]
            qsel = np.flatnonzero(match == f_idx)
            bm = jnp.broadcast_to(_allpass_bitmap(part.store.n),
                                  (qsel.size,
                                   (part.store.n + 31) // 32))
            out = search_batch(part.graph, part.store,
                               plan.queries[qsel], bm, plan.params,
                               use_pallas=self.use_pallas,
                               collect_trace=tracing)
            d, lids, stats = out[:3]
            rows = np.asarray(part.rows)
            lids = np.asarray(lids)
            dists[qsel] = np.asarray(d)
            ids[qsel] = np.where(lids >= 0,
                                 rows[np.maximum(lids, 0)], -1)
            for name in counters:
                counters[name][qsel] = np.asarray(getattr(stats, name))
            if tracing:
                sparts.append(_scatter_storage_stats(
                    self._account_partition(out[3], rows, qsel), qsel, q))
        if unmatched.size:
            fres = self.base.search(plan.queries[unmatched],
                                    plan.bitmaps[unmatched],
                                    plan.notes["caller_params"])
            dists[unmatched] = np.asarray(fres.dists)[:, :k]
            ids[unmatched] = np.asarray(fres.ids)[:, :k]
            if fres.stats is not None:
                for name in counters:
                    counters[name][unmatched] = np.asarray(
                        getattr(fres.stats, name))
            if fres.storage is not None:
                sparts.append(_scatter_storage_stats(fres.storage,
                                                     unmatched, q))
        # plan-time family match: each DISTINCT predicate bitmap in the
        # batch is compared against all F family bitmaps, words at a time
        # (PartitionedGraph.match dedupes the same way) — the only filter
        # work a matched query ever pays (the JAG accounting claim).  The
        # charge lands on each distinct bitmap's first query; queries
        # sharing the bitmap ride the memoized match.
        _, first = np.unique(np.asarray(plan.bitmaps), axis=0,
                             return_index=True)
        counters["filter_checks"][first] += (
            len(self.partitions.partitions) * int(plan.bitmaps.shape[1]))
        stats = SearchStats(**{name: jnp.asarray(v)
                               for name, v in counters.items()})
        sstats = merge_storage_stats(sparts) if sparts else None
        jd, ji = jnp.asarray(dists), jnp.asarray(ids)
        return SearchResult(dists=jd, ids=ji, stats=stats,
                            strategy="partitioned", plan=plan,
                            storage=sstats,
                            anytime=costmodel.evaluate_anytime(
                                stats, plan.params, self.store.dim, ji,
                                hop_cap=plan.params.max_hops))

    def _account_partition(self, trace, rows: np.ndarray,
                           qsel: np.ndarray):
        """Scatter a subgraph trace's first-touch stamps (Qg, n_f) to
        global row ids (Qg, n) and replay through the base layout."""
        n = self.store.n
        hs = np.asarray(trace["heap_steps"])
        isteps = np.asarray(trace["index_steps"])
        heap_g = np.full((qsel.size, n), TRACE_UNTOUCHED, np.int32)
        idx_g = np.full((qsel.size, n), TRACE_UNTOUCHED, np.int32)
        heap_g[:, rows] = hs
        idx_g[:, rows] = isteps
        rr = trace.get("rerank_rows")
        rr_g = None
        if rr is not None:
            rr = np.asarray(rr)
            rr_g = np.where(rr >= 0, rows[np.maximum(rr, 0)], -1)
        return self.storage.account_graph(heap_g, idx_g, rerank_rows=rr_g,
                                          quant=self.graph_quant == "sq8")


class ScannExecutor(BaseExecutor):
    """Filtered ScaNN (paper §2.3.7) behind the executor API.

    pipeline="batched" is the query-batched union-scan hot path
    (DESIGN.md §4, with optional query-block tiling); "vmapped" is the
    legacy per-query path kept as the equivalence oracle."""

    def __init__(self, index: ScannIndex, store: VectorStore,
                 pipeline: str = "batched", use_pallas: bool = False,
                 storage: Optional[StorageEngine] = None):
        if pipeline not in ("batched", "vmapped"):
            raise ValueError(f"unknown scann pipeline {pipeline!r}")
        if storage is not None:
            if pipeline != "batched":
                raise ValueError("storage accounting needs the batched "
                                 "scann pipeline")
            if storage.scann is None:
                raise ValueError("storage engine lacks a scann leaf "
                                 "layout; build it with index=")
        self.index = index
        self.store = store
        self.pipeline = pipeline
        self.use_pallas = use_pallas
        self.storage = storage
        self.name = "scann" if pipeline == "batched" else "scann_vmapped"

    def plan(self, queries, bitmaps, params: SearchParams) -> SearchPlan:
        if params.strategy != "scann":
            params = dataclasses.replace(params, strategy="scann")
        # Anytime budgets (DESIGN.md §10): ScaNN's leaf count is a static
        # shape, so budget enforcement is plan-time — clamp
        # num_leaves_to_search to what the budgets afford and flag the
        # batch via plan.notes.  Zero budgets short-circuit to (nl, False)
        # and the params object is untouched (bit-identicality).
        nl, clamped = leaves_within_budget(self.index, self.store, params)
        notes = None
        if clamped:
            params = dataclasses.replace(params, num_leaves_to_search=nl)
            notes = {"leaf_clamp": nl}
        return SearchPlan("scann", params, queries, bitmaps, notes=notes)

    def _anytime(self, plan: SearchPlan, ids):
        # flags come from the plan-time clamp, not the counters: the
        # clamped plan fits the budget by construction, so counter-derived
        # predicates would never fire (stats=None skips them)
        q = np.asarray(ids).shape[0]
        clamped = plan.notes is not None and "leaf_clamp" in plan.notes
        return costmodel.evaluate_anytime(
            None, plan.params, self.store.dim, ids,
            extra_budget=np.full((q,), clamped, bool))

    def execute(self, plan: SearchPlan) -> SearchResult:
        if self.storage is not None:
            d, ids, stats, trace = scann_search_batch(
                self.index, self.store, plan.queries, plan.bitmaps,
                plan.params, use_pallas=self.use_pallas, collect_trace=True)
            sstats = self.storage.account_scann(
                np.asarray(trace["leaves"]), np.asarray(trace["cand_rows"]),
                np.asarray(trace["cand_ok"]),
                accounting=plan.params.scann_page_accounting,
                query_block=plan.params.scann_query_block)
            return SearchResult(dists=d, ids=ids, stats=stats,
                                strategy="scann", plan=plan, storage=sstats,
                                anytime=self._anytime(plan, ids))
        fn = scann_search_batch if self.pipeline == "batched" \
            else scann_search_batch_vmapped
        d, ids, stats = fn(self.index, self.store, plan.queries,
                           plan.bitmaps, plan.params,
                           use_pallas=self.use_pallas)
        return SearchResult(dists=d, ids=ids, stats=stats, strategy="scann",
                            plan=plan, anytime=self._anytime(plan, ids))


@jax.jit
def _bitmap_popcount(bitmaps):
    """Per-query popcount over packed bitmap words. (Q, W) -> (Q,) int32."""
    return jax.lax.population_count(bitmaps).sum(axis=-1).astype(jnp.int32)


def _mask_bitmap_prefix(bm: np.ndarray, probes: np.ndarray) -> np.ndarray:
    """Zero every bitmap bit at row id >= probes[q] — the part of the
    seqscan a partial (budgeted) scan never reached, so the storage
    replay only charges pages the scan actually touched."""
    words = bm.shape[1]
    keep = np.clip(probes[:, None].astype(np.int64)
                   - np.arange(words, dtype=np.int64)[None, :] * 32, 0, 32)
    mask = np.where(keep >= 32, np.uint32(0xFFFFFFFF),
                    ((np.uint64(1) << keep.astype(np.uint64)) - 1)
                    .astype(np.uint32))
    return (bm & mask).astype(np.uint32)


def index_shape(store: VectorStore, index: Optional[ScannIndex] = None,
                graph_m: int = 16) -> costmodel.IndexShape:
    """Static shape facts for the predictive cost model — the public
    derivation shared by AdaptivePlanner and the benchmarks."""
    kw = dict(n=store.n, dim=store.dim, graph_m=graph_m)
    if index is not None:
        L, C, _ = index.leaf_tiles.shape
        if index.levels >= 2:
            B, Lb = index.branch_leaves.shape
            nb = max(1, -(-32 * 2 * B // L))
            cent = B + nb * Lb
        else:
            cent = L
        # average VALID rows per leaf (padded capacity C over-counts:
        # the stats only charge rowids >= 0)
        fill = max(1, round(store.n / L))
        kw.update(scann_leaves=L, scann_rows_per_leaf=min(fill, C),
                  scann_cent_scored=cent,
                  scann_pages_per_leaf=_quant_pages_per_leaf(index))
    return costmodel.IndexShape(**kw)


class BruteForceExecutor(BaseExecutor):
    """Exact filtered KNN (`bruteforce.filtered_knn`) with seqscan-semantic
    counters: every row is filter-checked; passing rows are fetched from
    the heap and scored.  Ground-truth recall by construction — the
    planner's refuge at very low selectivity, where (paper Fig. 9, left
    edge) every index strategy pays more than a scan of the survivors."""

    name = "bruteforce"

    def __init__(self, store: VectorStore,
                 storage: Optional[StorageEngine] = None):
        self.store = store
        self.storage = storage

    def plan(self, queries, bitmaps, params: SearchParams) -> SearchPlan:
        if params.strategy != "bruteforce":
            params = dataclasses.replace(params, strategy="bruteforce")
        # Anytime budgets (DESIGN.md §10): a page or deadline budget caps
        # how many passing rows the scan can afford to fetch+score — a
        # static row cap resolved at plan time (hop_budget has no meaning
        # for a seqscan and is ignored).  At least k rows always scan so
        # the last ladder rung returns a usable, flagged top-k.
        max_rows = self._budget_rows(params)
        notes = {"max_rows": max_rows} if max_rows is not None else None
        return SearchPlan("bruteforce", params, queries, bitmaps,
                          notes=notes)

    def _budget_rows(self, params: SearchParams) -> Optional[int]:
        if params.page_budget <= 0 and params.deadline_cycles <= 0:
            return None
        n = self.store.n
        ppv = heap_pages_per_vector(self.store.dim)
        rows = n
        if params.page_budget > 0:
            rows = min(rows, params.page_budget // ppv)
        if params.deadline_cycles > 0:
            w = costmodel.budget_cycle_weights(self.store.dim)
            per_row = w["distance_comps"] + ppv * w["page_accesses_heap"]
            fixed = n * w["filter_checks"]
            rows = min(rows, int(max(params.deadline_cycles - fixed, 0.0)
                                 // max(per_row, 1e-9)))
        rows = max(min(rows, n), params.k)
        return None if rows >= n else rows

    def execute(self, plan: SearchPlan) -> SearchResult:
        q = plan.queries.shape[0]
        n = self.store.n
        ppv = heap_pages_per_vector(self.store.dim)
        z = jnp.zeros((q,), jnp.int32)
        max_rows = (plan.notes or {}).get("max_rows")
        if max_rows is None:
            d, ids = filtered_knn(self.store, plan.queries, plan.bitmaps,
                                  plan.params.k)
            npass = _bitmap_popcount(plan.bitmaps)          # (Q,)
            stats = SearchStats(
                distance_comps=npass, filter_checks=z + n, hops=z,
                page_accesses_index=z, page_accesses_heap=npass * ppv,
                tmap_lookups=z, reorder_rows=z)
            truncated = np.zeros((q,), bool)
            scan_bitmaps = np.asarray(plan.bitmaps)
        else:
            d, ids, n_scored, probes, trunc = filtered_knn_partial(
                self.store, plan.queries, plan.bitmaps, plan.params.k,
                max_rows)
            stats = SearchStats(
                distance_comps=n_scored, filter_checks=probes, hops=z,
                page_accesses_index=z, page_accesses_heap=n_scored * ppv,
                tmap_lookups=z, reorder_rows=z)
            truncated = np.asarray(trunc)
            # the storage replay must see only the scanned prefix
            scan_bitmaps = _mask_bitmap_prefix(np.asarray(plan.bitmaps),
                                               np.asarray(probes))
        sstats = None
        if self.storage is not None:
            # the bitmap IS the seqscan trace: passing rows in row-id order
            sstats = self.storage.account_seqscan(scan_bitmaps)
        return SearchResult(dists=d, ids=ids, stats=stats,
                            strategy="bruteforce", plan=plan,
                            storage=sstats,
                            anytime=costmodel.evaluate_anytime(
                                None, plan.params, self.store.dim, ids,
                                extra_budget=truncated))


# ---------------------------------------------------------------------------
# The mutable delta tier's executor (DESIGN.md §12).
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k", "metric", "base_n"))
def _delta_scan(vectors, norms_sq, count, queries, bitmaps, k: int,
                metric: str, base_n: int):
    """Exact filtered scan of the capacity-padded delta buffer.

    The buffer has STATIC shape (capacity, dim) and only `count` (a
    traced scalar) changes as the tier fills — one compile per capacity,
    never per mutation.  Rows >= count and rows failing the bitmap (probed
    at their GLOBAL ids, so the caller's tombstone-composed filter bitmap
    applies unchanged) score +inf.  The distance expression is the same
    elementwise-plus-last-axis-sum `distance()` the bruteforce oracle
    evaluates, so merged results are bit-identical to a from-scratch
    rebuild, not approximately equal."""
    cap = vectors.shape[0]
    local = jnp.arange(cap)
    gids = base_n + local
    live = local < count
    passing = jax.vmap(lambda bm: probe_bitmap(bm, gids))(bitmaps) \
        & live[None, :]
    d = distance(metric, queries[:, None, :], vectors[None, :, :],
                 norms_sq[None, :])
    d = jnp.where(passing, d, jnp.inf)
    dists, idx = topk_smallest(d, min(k, cap))
    ids = jnp.where(jnp.isinf(dists), -1, base_n + idx)
    if k > cap:                       # static pad: tier smaller than k
        dists = jnp.pad(dists, ((0, 0), (0, k - cap)),
                        constant_values=jnp.inf)
        ids = jnp.pad(ids, ((0, 0), (0, k - cap)), constant_values=-1)
    return dists, ids, passing.sum(1).astype(jnp.int32)


class DeltaExecutor(BaseExecutor):
    """Exact scan over the LSM delta tier (storage.delta.DeltaTier) —
    the unindexed mutable tail every base strategy's top-k merges with
    (`core.mutable.MutableIndex` / `types.merge_topk`).

    Seqscan counter semantics scaled to the tier: every live delta row is
    filter-checked, passing rows are fetched full-width and scored
    (`costmodel.delta_scan_counters`).  With a `storage` engine attached
    (built with delta_capacity=) the per-query scan replays through the
    pool's "delta" segment."""

    name = "delta"

    def __init__(self, tier, metric: str,
                 storage: Optional[StorageEngine] = None):
        self.tier = tier
        self.metric = metric
        self.storage = storage

    def plan(self, queries, bitmaps, params: SearchParams) -> SearchPlan:
        if params.strategy != "delta":
            params = dataclasses.replace(params, strategy="delta")
        # snapshot the mutable tier at plan time: a consistent
        # (count, base_n, rows) view even if mutations land mid-request
        notes = {"count": int(self.tier.count),
                 "base_n": int(self.tier.base_n),
                 "vectors": np.array(self.tier.vectors, np.float32)}
        return SearchPlan("delta", params, queries, bitmaps, notes=notes)

    def execute(self, plan: SearchPlan) -> SearchResult:
        notes = plan.notes
        vecs = jnp.asarray(notes["vectors"])
        # eager per-row norms, the exact expression VectorStore.build uses
        nsq = jnp.sum(vecs * vecs, axis=-1)
        count = notes["count"]
        d, ids, npass = _delta_scan(vecs, nsq, jnp.int32(count),
                                    plan.queries, plan.bitmaps,
                                    plan.params.k, self.metric,
                                    notes["base_n"])
        q = plan.queries.shape[0]
        z = jnp.zeros((q,), jnp.int32)
        ppv = heap_pages_per_vector(vecs.shape[1])
        stats = SearchStats(
            distance_comps=npass, filter_checks=z + count, hops=z,
            page_accesses_index=z, page_accesses_heap=npass * ppv,
            tmap_lookups=z, reorder_rows=z)
        sstats = None
        if self.storage is not None:
            sstats = self.storage.account_delta_scan(count, q)
        return SearchResult(dists=d, ids=ids, stats=stats,
                            strategy="delta", plan=plan, storage=sstats,
                            anytime=costmodel.evaluate_anytime(
                                None, plan.params, vecs.shape[1], ids))


# ---------------------------------------------------------------------------
# The system-aware adaptive planner.
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("probe_leaves",))
def _leaf_local_selectivity(index: ScannIndex, queries, bitmaps,
                            probe_leaves: int):
    """Bitmap density inside each query's nearest `probe_leaves` ScaNN
    leaves — the correlation proxy numerator.  (Q,) float32.

    The centroid scan here is repeated by ScannExecutor when the planner
    picks scann — accepted cost (O(Q·L·dp), trivial next to the leaf
    scan) so the fixed executors' jitted entry points stay byte-for-byte
    the legacy ones (the equivalence guarantee)."""
    qp = project_query(index, queries)                        # (Q, dp)
    cents = index.leaf_centroids
    cn = jnp.sum(cents * cents, -1)
    d = (jnp.sum(qp * qp, -1)[:, None] + cn[None, :]
         - 2.0 * qp @ cents.T)                                # (Q, L)
    _, leaves = topk_smallest(d, probe_leaves)                # (Q, P)
    rows = index.leaf_rowids[leaves]                          # (Q, P, C)
    ok = jax.vmap(lambda bm, r: probe_bitmap(bm, r))(
        bitmaps, rows.reshape(rows.shape[0], -1))
    valid = (rows >= 0).reshape(rows.shape[0], -1)
    return (ok & valid).sum(-1) / jnp.maximum(valid.sum(-1), 1)


class AdaptivePlanner(BaseExecutor):
    """Per-batch system-aware strategy selection (DESIGN.md §6).

    plan():  s_q   = popcount(bitmap_q)/n           (exact, ~n/32 word reads)
             γ     = mean local leaf density / mean s  (ScaNN-probe proxy;
                     1.0 when no ScaNN index is registered)
             pick  = argmin over recall-feasible candidates of
                     predict_cycles(strategy, shape, params, s̄, γ)
    execute(): delegates to the chosen fixed executor, then adds the
    planning overhead to the counters (n/32 filter-word reads per query
    plus the proxy's centroid scans + leaf probes) so regret accounting
    stays honest.

    With a `storage` engine attached the dispatch becomes
    warm-cache-aware (DESIGN.md §8): plan() snapshots the buffer pool's
    per-segment residency (`BufferPoolState`) and every candidate's
    predicted cycles include its expected miss penalty — a strategy whose
    index pages are already resident gets cheaper, which is the paper's
    "system-aware decision" made literal at the buffer-manager level.
    """

    name = "adaptive"

    def __init__(self, candidates: Mapping[str, Executor],
                 store: VectorStore,
                 constants: costmodel.CostConstants = costmodel.SYSTEM,
                 graph_m: int = 16, probe_leaves: int = 4,
                 recall_margin: float = 2.0,
                 scann_recall_margin: float = 10.0,
                 storage: Optional[StorageEngine] = None):
        if not candidates:
            raise ValueError("AdaptivePlanner needs at least one candidate")
        for name, ex in candidates.items():
            kind = _strategy_kind(ex)
            if kind not in costmodel.PREDICTABLE_STRATEGIES:
                raise ValueError(
                    f"candidate {name!r} ({kind!r}) has no predictive "
                    f"model; supported: {costmodel.PREDICTABLE_STRATEGIES}")
        self.candidates = dict(candidates)
        self.store = store
        self.constants = constants
        self.graph_m = graph_m
        self.probe_leaves = probe_leaves
        self.recall_margin = recall_margin
        self.scann_recall_margin = scann_recall_margin
        self.storage = storage
        self._scann = next((ex for ex in self.candidates.values()
                            if isinstance(ex, ScannExecutor)), None)
        # Pool-measured per-batch unique-fetch fraction of the last graph
        # dispatch (StorageStats.unique_fraction): replaces the
        # FRONTIER_PAGE_AMORT calibration constant in subsequent
        # predictions (costmodel.engine_scale) — the ROADMAP
        # "per-batch measurement instead of a constant" follow-up.
        self._measured_unique: Optional[float] = None
        # Memoized per-batch (selectivity, γ) — see _selectivity_proxy.
        self._proxy_key: Optional[tuple] = None
        self._proxy_val: Optional[tuple] = None

    # -- shape facts for the predictive model --------------------------------
    def _shape(self) -> costmodel.IndexShape:
        return index_shape(
            self.store,
            self._scann.index if self._scann is not None else None,
            self.graph_m)

    def _recall_feasible(self, strategy: str, shape: costmodel.IndexShape,
                         params: SearchParams, s_eff: float) -> bool:
        """Cheap guards against picking a strategy whose expected candidate
        pool cannot even contain k passing rows (decision boundaries,
        DESIGN.md §6).  bruteforce is always feasible (exact)."""
        k = params.k * self.recall_margin
        if strategy == "scann":
            # the opened leaves must hold comfortably more passing rows
            # than k — ScaNN's recall collapses quietly when the predicate
            # is sparse/anti-correlated (few survivors land in the nearest
            # leaves), so the margin is deliberately wide
            nl = min(params.num_leaves_to_search, shape.scann_leaves or 1)
            return s_eff * nl * (shape.scann_rows_per_leaf or 0) >= \
                params.k * self.scann_recall_margin
        if strategy in ("acorn", "navix"):
            # predicate subgraph must hold at least ~ef nodes to navigate
            return shape.n * s_eff * costmodel.FILTER_FIRST_POOL >= \
                max(params.ef_search, k)
        if strategy in ("sweeping", "iterative_scan", "sweeping_excl"):
            # traversal must reach k passing rows within the hop budget
            # (pruning never drops a passing candidate, so the exclusion
            # tier inherits sweeping's reachability law unchanged)
            hops = min(max(params.ef_search, 2 * params.k) / max(s_eff, 1e-9),
                       float(params.max_hops))
            return costmodel.GRAPH_NEW_PER_HOP * hops * s_eff >= k
        return True

    def _batch_feasible(self, ex: Executor, bitmaps) -> bool:
        """Batch-shape feasibility the closed-form laws can't see: the
        partitioned tier answers a batch only when EVERY query's bitmap
        equals a registered family bitmap and the partitions are fresh —
        anything else would silently route through its fallback and the
        prediction would price the wrong machinery."""
        if isinstance(ex, PartitionedGraphExecutor):
            if ex.partitions.built_n != ex.store.n:
                return False
            return bool((np.asarray(ex.partitions.match(bitmaps)) >= 0)
                        .all())
        return True

    def _selectivity_proxy(self, queries, bitmaps):
        """Memoized (per-query selectivity, correlation proxy γ) for one
        batch, keyed by a crc of the raw bytes.  Regret sweeps and serving
        loops replan the same workload as the candidate menu grows, and
        the popcount + leaf-probe proxies are menu-independent — one
        computation per distinct batch keeps planning cost flat from the
        6-candidate menu to the 9-candidate one.  The CHARGED overhead
        (filter-word reads + probe fc/dc in execute()) is a property of
        the proxy computation, not the menu, and is unchanged."""
        key = (zlib.crc32(np.asarray(bitmaps).tobytes()),
               zlib.crc32(np.ascontiguousarray(
                   np.asarray(queries, np.float32)).tobytes()))
        if self._proxy_key == key:
            return self._proxy_val
        n = self.store.n
        sel = np.asarray(_bitmap_popcount(bitmaps)).astype(np.float64) / n
        gamma = 1.0
        if self._scann is not None:
            local = np.asarray(_leaf_local_selectivity(
                self._scann.index, queries, bitmaps, self.probe_leaves))
            gamma = float(np.clip(local.mean()
                                  / max(float(sel.mean()), 1.0 / n),
                                  0.05, 20.0))
        self._proxy_key, self._proxy_val = key, (sel, gamma)
        return sel, gamma

    def plan(self, queries, bitmaps, params: SearchParams) -> SearchPlan:
        n = self.store.n
        sel, gamma = self._selectivity_proxy(queries, bitmaps)
        s_mean = float(sel.mean())
        shape = self._shape()
        s_eff = min(max(s_mean * gamma, 1.0 / n), 1.0)
        batch_q = int(queries.shape[0])
        pool_state = self.storage.state() if self.storage is not None \
            else None
        # predict with each candidate's RESOLVED params (strategy +
        # graph_quant), so e.g. the sweeping_sq8 candidate is priced on
        # the quantized tier it would actually execute
        preds = {name: costmodel.predict_cycles(
            _strategy_kind(ex), shape, _candidate_params(ex, params),
            s_mean, gamma, self.constants, batch_q=batch_q,
            pool_state=pool_state,
            measured_unique_frac=self._measured_unique)
            for name, ex in self.candidates.items()}
        feasible = {name: p for name, p in preds.items()
                    if self._recall_feasible(_strategy_kind(
                        self.candidates[name]), shape, params, s_eff)
                    and self._batch_feasible(self.candidates[name], bitmaps)}
        # never empty: fall back to argmin, but a batch-infeasible
        # candidate (partitioned with an unmatched query) stays out even
        # then — executing it would route the wrong machinery
        pool = feasible \
            or {nm: p for nm, p in preds.items()
                if self._batch_feasible(self.candidates[nm], bitmaps)} \
            or preds
        chosen = min(pool, key=pool.get)
        inner = self.candidates[chosen].plan(queries, bitmaps, params)
        return SearchPlan(strategy=chosen, params=inner.params,
                          queries=queries, bitmaps=bitmaps,
                          est_selectivity=sel, correlation_proxy=gamma,
                          predicted_cycles=preds, notes=inner.notes)

    def execute(self, plan: SearchPlan) -> SearchResult:
        chosen = self.candidates[plan.strategy]
        res = self.candidates[plan.strategy].execute(plan)
        if res.storage is not None and isinstance(chosen, GraphExecutor) \
                and chosen.graph_quant == "none":
            # full-precision graph batch ran through the pool: keep its
            # measured page-sharing for the next plan's engine_scale.
            # Only the f32 tier updates it — FRONTIER_CALIB_UNIQUE was
            # calibrated on f32 heap geometry, and the 4×-denser qheap
            # shares pages structurally more (a sq8 measurement would
            # wrongly discount every f32 candidate too).
            self._measured_unique = res.storage.unique_fraction()
        if res.stats is not None:
            # planning overhead: popcount reads every bitmap word (n/32
            # filter-word probes) + the proxy's centroid scan and leaf
            # probes — charged so the regret curve includes the planner.
            words = int(plan.bitmaps.shape[1])
            probe_fc = 0
            probe_dc = 0
            if self._scann is not None:
                idx = self._scann.index
                probe_fc = self.probe_leaves * idx.leaf_rowids.shape[1]
                probe_dc = idx.leaf_centroids.shape[0]
            st = res.stats
            stats = dataclasses.replace(
                st,
                filter_checks=st.filter_checks + words + probe_fc,
                distance_comps=st.distance_comps + probe_dc)
            res = dataclasses.replace(res, stats=stats, plan=plan)
        return res


def _strategy_kind(ex: Executor) -> str:
    """Predictive-model strategy key for an executor instance (quant
    variants of a graph strategy share its predictive model; the
    exclusion and partitioned tiers have their own laws)."""
    if isinstance(ex, ScannExecutor):
        return "scann"
    if isinstance(ex, PartitionedGraphExecutor):
        return "partitioned"
    if isinstance(ex, GraphExecutor) and ex.exclusion is not None:
        return "sweeping_excl"
    return getattr(ex, "strategy", ex.name)


def _candidate_params(ex: Executor, params: SearchParams) -> SearchParams:
    """The params the candidate would resolve in plan() — what its
    prediction must be priced on (strategy + graph_quant for graph
    executors)."""
    if isinstance(ex, PartitionedGraphExecutor):
        return dataclasses.replace(params, strategy="unfiltered",
                                   graph_quant=ex.graph_quant,
                                   exclusion="none")
    if isinstance(ex, GraphExecutor):
        return dataclasses.replace(
            params, strategy=ex.strategy, graph_quant=ex.graph_quant,
            exclusion="none" if ex.exclusion is None else "prune")
    return params


# ---------------------------------------------------------------------------
# Registry — the one dispatch point for benchmarks/serving/launch.
# ---------------------------------------------------------------------------

GRAPH_SQ8_METHODS = tuple(f"{s}_sq8" for s in GRAPH_STRATEGIES)
# Selectivity-aware tiers (DESIGN.md §14): exclusion-pruned sweeping and
# the attribute-partitioned graph, each with an SQ8 shadow variant.
EXCL_METHODS = ("sweeping_excl", "sweeping_excl_sq8")
PARTITIONED_METHODS = ("partitioned", "partitioned_sq8")
REGISTERED_METHODS = GRAPH_STRATEGIES + GRAPH_SQ8_METHODS + EXCL_METHODS \
    + PARTITIONED_METHODS + ("scann", "scann_vmapped", "bruteforce",
                             "adaptive")


def _parse_graph_method(method: str) -> tuple[str, str]:
    """"sweeping_sq8" -> ("sweeping", "sq8"); plain names pass through."""
    if method.endswith("_sq8") and method[:-4] in GRAPH_STRATEGIES:
        return method[:-4], "sq8"
    return method, "none"


def make_executor(method: str, store: VectorStore, *,
                  graph: Optional[HNSWGraph] = None,
                  index: Optional[ScannIndex] = None,
                  use_pallas: bool = False,
                  constants: costmodel.CostConstants = costmodel.SYSTEM,
                  graph_m: int = 16,
                  storage: Optional[StorageEngine] = None,
                  exclusion: Optional[ExclusionIndex] = None,
                  partitions: Optional[PartitionedGraph] = None,
                  planner_candidates: tuple[str, ...] = (
                      "bruteforce", "scann", "sweeping", "sweeping_sq8",
                      "navix", "iterative_scan")) -> Executor:
    """Build the executor for `method`.

    Graph strategies need `graph`; their "<strategy>_sq8" variants run
    the SQ8 quantized-traversal tier (DESIGN.md §9 — the store is
    shadow-quantized here if it isn't already); "scann"/"scann_vmapped"
    need `index`; the selectivity-aware tiers (DESIGN.md §14) need their
    build artifacts: "sweeping_excl[_sq8]" needs `exclusion=`
    (core.exclusion.build_exclusion) and "partitioned[_sq8]" needs
    `partitions=` (hnsw.build_graph_partitioned, with `graph=` as the
    unmatched-query fallback).  "adaptive" builds every candidate the
    provided components support — name the new tiers in
    `planner_candidates` to put them on the menu.  `storage` attaches a
    paged storage engine (DESIGN.md §8): results carry measured
    StorageStats, and for "adaptive" ONE shared pool backs every
    candidate AND feeds residency + measured per-batch page sharing into
    the planner's predictions (warm-cache-aware, engine-amortization-
    aware dispatch)."""
    def _excl_executor(quant: str, st: VectorStore) -> GraphExecutor:
        if graph is None or exclusion is None:
            raise ValueError("'sweeping_excl' variants need graph= and "
                             "exclusion=")
        return GraphExecutor(graph, st, strategy="sweeping",
                             use_pallas=use_pallas, storage=storage,
                             graph_quant=quant, exclusion=exclusion)

    def _part_executor(quant: str, st: VectorStore) -> Executor:
        if partitions is None:
            raise ValueError("'partitioned' variants need partitions=")
        fallback = None if graph is None else GraphExecutor(
            graph, st, strategy="sweeping", use_pallas=use_pallas,
            storage=storage, graph_quant=quant)
        return PartitionedGraphExecutor(partitions, st, base=fallback,
                                        use_pallas=use_pallas,
                                        storage=storage, graph_quant=quant)

    if method in EXCL_METHODS:
        quant = "sq8" if method.endswith("_sq8") else "none"
        return _excl_executor(quant, quantize_store(store)
                              if quant == "sq8" else store)
    if method in PARTITIONED_METHODS:
        quant = "sq8" if method.endswith("_sq8") else "none"
        return _part_executor(quant, quantize_store(store)
                              if quant == "sq8" else store)
    base, quant = _parse_graph_method(method)
    if base in GRAPH_STRATEGIES:
        if graph is None:
            raise ValueError(f"{method!r} needs graph=")
        if quant == "sq8":
            store = quantize_store(store)
        return GraphExecutor(graph, store, strategy=base,
                             use_pallas=use_pallas, storage=storage,
                             graph_quant=quant)
    if method in ("scann", "scann_vmapped"):
        if index is None:
            raise ValueError(f"{method!r} needs index=")
        return ScannExecutor(index, store,
                             pipeline="batched" if method == "scann"
                             else "vmapped", use_pallas=use_pallas,
                             storage=storage)
    if method == "bruteforce":
        return BruteForceExecutor(store, storage=storage)
    if method == "adaptive":
        if any(_parse_graph_method(n)[1] == "sq8" or n.endswith("_sq8")
               for n in planner_candidates) and graph is not None:
            store = quantize_store(store)
        cands: dict[str, Executor] = {}
        for name in planner_candidates:
            cbase, cquant = _parse_graph_method(name)
            if name == "bruteforce":
                cands[name] = BruteForceExecutor(store, storage=storage)
            elif name in EXCL_METHODS:
                if graph is not None and exclusion is not None:
                    cands[name] = _excl_executor(
                        "sq8" if name.endswith("_sq8") else "none", store)
            elif name in PARTITIONED_METHODS:
                if partitions is not None:
                    cands[name] = _part_executor(
                        "sq8" if name.endswith("_sq8") else "none", store)
            elif cbase in GRAPH_STRATEGIES and graph is not None:
                cands[name] = GraphExecutor(graph, store, strategy=cbase,
                                            use_pallas=use_pallas,
                                            storage=storage,
                                            graph_quant=cquant)
            elif name in ("scann", "scann_vmapped") and index is not None:
                cands[name] = ScannExecutor(
                    index, store, pipeline="batched" if name == "scann"
                    else "vmapped", use_pallas=use_pallas,
                    storage=storage if name == "scann" else None)
        return AdaptivePlanner(cands, store, constants=constants,
                               graph_m=graph_m, storage=storage)
    raise ValueError(
        f"unknown method {method!r}; registered: {REGISTERED_METHODS}")
