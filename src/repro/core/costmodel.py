"""System-tax cost model (paper §3.4, §6.2, Fig. 10).

Translates the measured SearchStats counters into modeled CPU cycles under
two architectural regimes:

  SYSTEM  — PostgreSQL-like page engine: every page access pays buffer-pool
            lookup + pin + shared lock + release; every scored vector pays
            tuple materialization (palloc + copy); heaptid resolution costs
            a translation-map hash probe (if enabled) or an index-page
            access (if not — the Fig. 13 ablation).
  LIBRARY — HNSWLib-like flat memory: neighbor access is a pointer
            dereference, no locks, unified ids (no translation).

Defaults are calibrated so an OpenAI-5M-shaped workload (d=1536, graph
M=32) reproduces the paper's Fig. 10 component shares (system overheads
dominating; vector-retrieval ≈ 300M cycles for Sweeping at 1 % selectivity)
and Table 2's Dist/Filt relative-cost column. The same counters under the
two regimes reproduce Fig. 1's crossover-point shift.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

import numpy as np

from repro.core.types import SearchStats


@dataclasses.dataclass(frozen=True)
class CostConstants:
    page_access: float          # pin + lock + read + release (cycles)
    tuple_materialize: float    # palloc + copy, per byte
    distance_per_dim: float     # SIMD distance cycles per dimension
    filter_check: float         # bitmap probe
    tmap_lookup: float          # in-memory hash probe
    reorder_sort_per_row: float  # reordering sort/merge work


# Calibrated to reproduce Fig. 10 / Table 2 shapes (see module docstring).
SYSTEM = CostConstants(
    page_access=2400.0,        # buffer lookup ~ few hundred ns @ ~3 GHz
    tuple_materialize=0.25,    # per byte copied into query context
    distance_per_dim=2.0,      # scalar-ish per-dim cost inside PG fmgr
    filter_check=18.0,
    tmap_lookup=40.0,
    reorder_sort_per_row=60.0,
)

LIBRARY = CostConstants(
    page_access=12.0,          # pointer dereference + cache miss amortized
    tuple_materialize=0.0,     # zero-copy
    distance_per_dim=0.5,      # SIMD-optimized distance
    filter_check=15.0,         # bitmap probe cost is architecture-neutral
    tmap_lookup=0.0,           # unified identifiers
    reorder_sort_per_row=30.0,
)


def cycle_breakdown(stats: SearchStats, dim: int,
                    constants: CostConstants = SYSTEM) -> dict[str, float]:
    """Per-component modeled cycles for one query (Fig. 10 bars)."""
    s = {k: float(np.asarray(v).mean()) for k, v in stats.as_dict().items()} \
        if _is_batched(stats) else {k: float(np.asarray(v))
                                    for k, v in stats.as_dict().items()}
    vec_bytes = dim * 4
    comp = {
        "index_page_access": s["page_accesses_index"] * constants.page_access,
        "vector_retrieval": s["page_accesses_heap"] * constants.page_access
        + s["distance_comps"] * vec_bytes * constants.tuple_materialize,
        "distance_compute": s["distance_comps"] * dim
        * constants.distance_per_dim,
        "filter_checks": s["filter_checks"] * constants.filter_check,
        "translation_map": s["tmap_lookups"] * constants.tmap_lookup,
        "reordering": s["reorder_rows"] * constants.reorder_sort_per_row,
    }
    comp["total"] = sum(comp.values())
    return comp


def _is_batched(stats: SearchStats) -> bool:
    return np.asarray(stats.distance_comps).ndim > 0


def modeled_qps(stats: SearchStats, dim: int,
                constants: CostConstants = SYSTEM,
                clock_hz: float = 3.0e9, threads: int = 16,
                thread_overhead: Mapping[int, float] | None = None) -> float:
    """Modeled queries/second at a given concurrency.

    `thread_overhead` models the paper's Table 7 contention amplification
    (cycles inflate with concurrency); default +50 % at 16T.
    """
    cycles = cycle_breakdown(stats, dim, constants)["total"]
    amp = 1.0
    if threads > 1:
        amp = (thread_overhead or {16: 1.5}).get(threads, 1.5)
    per_query_s = cycles * amp / clock_hz
    return threads / per_query_s


def stats_table_row(stats: SearchStats) -> dict[str, float]:
    """Mean counters over a query batch — one row of the paper's Table 6."""
    return {k: float(np.asarray(v).mean())
            for k, v in stats.as_dict().items()}
