"""System-tax cost model (paper §3.4, §6.2, Fig. 10).

Two modes share one set of per-operation constants:

  post-hoc     — `cycle_breakdown` translates MEASURED SearchStats counters
                 into modeled CPU cycles (Fig. 10 bars, Table 7 rows);
  predictive   — `predict_counters`/`predict_cycles` produce closed-form
                 EXPECTED counters per strategy as a function of
                 (n, dim, selectivity estimate, correlation proxy, index
                 shape), before running anything.  This is what turns the
                 paper's "the best strategy is a system-aware decision"
                 finding (Fig. 1 crossover, §6.2) into an actual planner:
                 `executor.AdaptivePlanner` evaluates `predict_cycles` for
                 every registered strategy per query batch and dispatches
                 to the argmin.  Equations in DESIGN.md §6.

The constants translate counters into cycles under two regimes:

  SYSTEM  — PostgreSQL-like page engine: every page access pays buffer-pool
            lookup + pin + shared lock + release; every scored vector pays
            tuple materialization (palloc + copy); heaptid resolution costs
            a translation-map hash probe (if enabled) or an index-page
            access (if not — the Fig. 13 ablation).
  LIBRARY — HNSWLib-like flat memory: neighbor access is a pointer
            dereference, no locks, unified ids (no translation).

Defaults are calibrated so an OpenAI-5M-shaped workload (d=1536, graph
M=32) reproduces the paper's Fig. 10 component shares (system overheads
dominating; vector-retrieval ≈ 300M cycles for Sweeping at 1 % selectivity)
and Table 2's Dist/Filt relative-cost column. The same counters under the
two regimes reproduce Fig. 1's crossover-point shift.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Optional

import numpy as np

from repro.core.types import (AnytimeInfo, SearchParams, SearchStats,
                              heap_pages_per_vector,
                              quant_heap_pages_per_vector)


@dataclasses.dataclass(frozen=True)
class CostConstants:
    page_access: float          # buffer HIT: pin + lock + read + release
    tuple_materialize: float    # palloc + copy, per byte
    distance_per_dim: float     # SIMD distance cycles per dimension
    filter_check: float         # bitmap probe
    tmap_lookup: float          # in-memory hash probe
    reorder_sort_per_row: float  # reordering sort/merge work
    # Buffer-pool MISS multiplier (DESIGN.md §8): a missed page costs
    # page_access * page_miss_extra (read into shared buffers from the
    # OS cache / storage).  1.0 = flat memory, no pool.
    page_miss_extra: float = 1.0
    # Mesh-sharded traversal (DESIGN.md §13): cycles per byte moved by
    # the beam-exchange collectives.  ICI roofline is ~6 B/cycle
    # (~0.17 cy/B); padded for launch latency + the small-message regime
    # the per-hop reductions live in.  Single-device predictions never
    # read it (the collective volume is 0 at num_shards == 1).
    collective_per_byte: float = 0.5


# Calibrated to reproduce Fig. 10 / Table 2 shapes (see module docstring).
SYSTEM = CostConstants(
    page_access=2400.0,        # buffer lookup ~ few hundred ns @ ~3 GHz
    tuple_materialize=0.25,    # per byte copied into query context
    distance_per_dim=2.0,      # scalar-ish per-dim cost inside PG fmgr
    filter_check=18.0,
    tmap_lookup=40.0,
    reorder_sort_per_row=60.0,
    page_miss_extra=10.0,      # OS-page-cache read ~ few µs vs ~100s ns hit
)

LIBRARY = CostConstants(
    page_access=12.0,          # pointer dereference + cache miss amortized
    tuple_materialize=0.0,     # zero-copy
    distance_per_dim=0.5,      # SIMD-optimized distance
    filter_check=15.0,         # bitmap probe cost is architecture-neutral
    tmap_lookup=0.0,           # unified identifiers
    reorder_sort_per_row=30.0,
    page_miss_extra=1.0,       # flat memory: nothing to miss
)


GRAPH_STRATEGIES = ("unfiltered", "sweeping", "acorn", "navix",
                    "iterative_scan")

# Frontier-engine page-cost amortization (DESIGN.md §7): the batch-
# synchronous engine fetches each superstep's candidate union once for the
# whole batch (measured unique-fetch fraction ≈ 0.83–0.93 for 32 distinct
# queries on the bench workloads) and runs the fetch+probe as batched
# gathers instead of Q per-query scalar chains — together the effective
# per-page cost lands at roughly half the per-query engine's (the ≥3×
# wall-clock win in BENCH_frontier.json is page/fetch-side; distance FLOPs
# and filter probes are counter-for-counter unchanged).  A single query
# amortizes nothing (engine_scale returns None at batch_q ≤ 1).
FRONTIER_PAGE_AMORT = 0.5
# The unique-fetch fraction FRONTIER_PAGE_AMORT was calibrated against
# (measured 0.83–0.93 for 32 distinct queries — DESIGN.md §7; midpoint).
# When a StorageEngine measures the batch's actual page-sharing
# (StorageStats.unique_fraction), the amortization becomes a per-batch
# measurement: amort = FRONTIER_PAGE_AMORT · measured / CALIB — e.g. a
# centroid-routed batch whose queries share most pages measures a low
# unique fraction and earns a proportionally deeper discount
# (ROADMAP "storage-engine follow-ups").
FRONTIER_CALIB_UNIQUE = 0.88


def engine_scale(strategy: str, params: SearchParams,
                 batch_q: int = 1,
                 measured_unique_frac: Optional[float] = None
                 ) -> Optional[dict[str, float]]:
    """Per-component cycle multipliers for the execution engine that will
    actually run `strategy` (None = legacy per-query costs).  Applied
    identically by the planner's predictions and the post-hoc breakdowns
    so regret accounting stays in one currency.

    `measured_unique_frac` — a pool-measured per-batch unique-fetch
    fraction (StorageStats.unique_fraction) — replaces the
    FRONTIER_PAGE_AMORT constant with the measured amortization, anchored
    at the constant's calibration point (FRONTIER_CALIB_UNIQUE)."""
    if strategy not in GRAPH_STRATEGIES or batch_q <= 1:
        return None
    if params.graph_exec_mode != "frontier":
        return None
    amort = FRONTIER_PAGE_AMORT
    if measured_unique_frac is not None:
        amort = min(1.0, max(
            0.05, FRONTIER_PAGE_AMORT * measured_unique_frac
            / FRONTIER_CALIB_UNIQUE))
    return {"index_page_access": amort, "vector_retrieval": amort}


def component_cycles(counters: Mapping[str, float], dim: int,
                     constants: CostConstants = SYSTEM,
                     scale: Optional[Mapping[str, float]] = None,
                     graph_quant: str = "none") -> dict[str, float]:
    """Per-component modeled cycles for one query from a counter mapping
    (the Table 6 column names).  Shared by the post-hoc path (measured
    counters) and the predictive path (closed-form expected counters).
    `scale` (see `engine_scale`) multiplies named components — the
    engine-mode-aware weights.

    `graph_quant="sq8"` (DESIGN.md §9) prices the quantized-traversal
    tier: traversal rows materialize 1 byte/dim (int8 shadow rows)
    instead of 4, while the `reorder_rows` exact-rerank fetches stay
    full-width — page *hit* costs are unchanged (a logical access pins a
    page either way); the density win lands in the measured/predicted
    MISS side (`cache_miss_penalty`)."""
    vec_bytes = dim * 4
    if graph_quant == "sq8":
        rr = counters["reorder_rows"]
        trav_dc = max(counters["distance_comps"] - rr, 0.0)
        materialize = (trav_dc * dim + rr * vec_bytes) \
            * constants.tuple_materialize
    else:
        materialize = counters["distance_comps"] * vec_bytes \
            * constants.tuple_materialize
    comp = {
        "index_page_access": counters["page_accesses_index"]
        * constants.page_access,
        "vector_retrieval": counters["page_accesses_heap"]
        * constants.page_access + materialize,
        "distance_compute": counters["distance_comps"] * dim
        * constants.distance_per_dim,
        "filter_checks": counters["filter_checks"] * constants.filter_check,
        "translation_map": counters["tmap_lookups"] * constants.tmap_lookup,
        "reordering": counters["reorder_rows"]
        * constants.reorder_sort_per_row,
    }
    if scale:
        for k, f in scale.items():
            comp[k] *= f
    comp["total"] = sum(comp.values())
    return comp


# Which page segment (storage/engine.py) holds a strategy's *index* pages;
# every strategy's row fetches hit the "heap" segment.
def index_segment(strategy: str) -> Optional[str]:
    if strategy == "scann":
        return "scann"
    if strategy in GRAPH_STRATEGIES:
        return "graph"
    return None                     # bruteforce: seqscan, no index


def cache_miss_penalty(counters: Mapping[str, float], strategy: str,
                       pool_state, constants: CostConstants = SYSTEM,
                       graph_quant: str = "none",
                       dim: Optional[int] = None) -> float:
    """Expected extra cycles from buffer-pool misses, per query
    (DESIGN.md §8).  `pool_state` is a storage.BufferPoolState; the
    expected miss fraction of a segment's accesses is 1 − residency
    (uniform-touch approximation).  With page_miss_extra == 1 (LIBRARY)
    or a fully warm pool this is 0 and predictions reduce to the classic
    ones.

    Under graph_quant="sq8" (needs `dim`), the traversal's row fetches
    probe the dense "qheap" shadow segment — 4× fewer pages, so it warms
    ~4× faster and its residency-driven miss fraction drops sooner —
    while the rerank's full-width fetches (`reorder_rows` pages) probe
    "heap" (DESIGN.md §9)."""
    if pool_state is None or constants.page_miss_extra <= 1.0:
        return 0.0
    extra = constants.page_access * (constants.page_miss_extra - 1.0)
    if graph_quant == "sq8" and dim is not None:
        rr_pages = counters["reorder_rows"] * heap_pages_per_vector(dim)
        trav_pages = max(counters["page_accesses_heap"] - rr_pages, 0.0)
        pen = trav_pages * pool_state.miss_fraction("qheap") * extra \
            + rr_pages * pool_state.miss_fraction("heap") * extra
    else:
        pen = counters["page_accesses_heap"] * \
            pool_state.miss_fraction("heap") * extra
    seg = index_segment(strategy)
    if seg is not None:
        pen += counters["page_accesses_index"] * \
            pool_state.miss_fraction(seg) * extra
    return pen


def measured_miss_penalty(storage_stats, batch_q: int,
                          constants: CostConstants = SYSTEM) -> float:
    """Per-query extra cycles from MEASURED pool misses (a
    storage.StorageStats) — the post-hoc currency matching
    `cache_miss_penalty`'s predictions, for warm-cache regret accounting
    (benchmarks/bench_storage.py)."""
    extra = constants.page_access * (constants.page_miss_extra - 1.0)
    return storage_stats.miss_total * extra / max(batch_q, 1)


def cycle_breakdown(stats: SearchStats, dim: int,
                    constants: CostConstants = SYSTEM,
                    scale: Optional[Mapping[str, float]] = None,
                    graph_quant: str = "none") -> dict[str, float]:
    """Per-component modeled cycles for one query (Fig. 10 bars)."""
    s = {k: float(np.asarray(v).mean()) for k, v in stats.as_dict().items()} \
        if _is_batched(stats) else {k: float(np.asarray(v))
                                    for k, v in stats.as_dict().items()}
    return component_cycles(s, dim, constants, scale, graph_quant)


def _is_batched(stats: SearchStats) -> bool:
    return np.asarray(stats.distance_comps).ndim > 0


def modeled_qps(stats: SearchStats, dim: int,
                constants: CostConstants = SYSTEM,
                clock_hz: float = 3.0e9, threads: int = 16,
                thread_overhead: Mapping[int, float] | None = None) -> float:
    """Modeled queries/second at a given concurrency.

    `thread_overhead` models the paper's Table 7 contention amplification
    (cycles inflate with concurrency); default +50 % at 16T.
    """
    cycles = cycle_breakdown(stats, dim, constants)["total"]
    amp = 1.0
    if threads > 1:
        amp = (thread_overhead or {16: 1.5}).get(threads, 1.5)
    per_query_s = cycles * amp / clock_hz
    return threads / per_query_s


def stats_table_row(stats: SearchStats) -> dict[str, float]:
    """Mean counters over a query batch — one row of the paper's Table 6."""
    return {k: float(np.asarray(v).mean())
            for k, v in stats.as_dict().items()}


# ---------------------------------------------------------------------------
# Mesh-sharded traversal terms (DESIGN.md §13).
#
# The sharded frontier engine's extra cost over 1/S of the single-device
# cycles is pure collective volume, in two regimes:
#
#   lockstep (E=1):  every superstep all-reduces the candidate block's
#       owner-masked distances (pmin, f32) and adjacency entries (pmax,
#       int32) — 8 B per scored candidate, moved ~2·(S-1)/S times by a
#       ring all-reduce.  distance_comps counts exactly those candidates.
#   drift (E>1):     every E supersteps each shard all-gathers the other
#       shards' (dist, id) beams — ef_search · 8 B · (S-1) received per
#       exchange, ceil(hops/E) exchanges.
# ---------------------------------------------------------------------------

def beam_exchange_bytes(counters: Mapping[str, float], params: SearchParams,
                        num_shards: int) -> float:
    """Per-query collective bytes of the sharded frontier engine."""
    S = int(num_shards)
    if S <= 1:
        return 0.0
    E = max(1, int(params.beam_exchange_interval))
    if E == 1:
        return 8.0 * counters["distance_comps"] * 2.0 * (S - 1) / S
    exchanges = -(-counters["hops"] // E)
    return 8.0 * params.ef_search * exchanges * (S - 1)


def sharded_cycle_summary(stats: SearchStats, params: SearchParams,
                          dim: int, num_shards: int,
                          constants: CostConstants = SYSTEM,
                          graph_quant: str = "none",
                          per_shard_storage=None, batch_q: int = 1,
                          clock_hz: float = 3.0e9, threads: int = 16
                          ) -> dict[str, float]:
    """Aggregate modeled cost of one sharded batch (bench_sharding.py).

    The single-device cycle total parallelizes across shards (each shard
    scores/fetches only its owned rows); on top ride the beam-exchange
    collective term and — when the per-shard StorageStats from a
    `ShardedStorageAccountant` replay are given — a straggler term: the
    batch finishes with the SLOWEST shard's measured miss penalty, not
    the mean (`max - mean` of the per-shard penalties).  Returns the
    per-point record the sharding bench emits: cycles/query, collective
    bytes + cycles, straggler extra, and aggregated modeled QPS."""
    row = stats_table_row(stats)
    base = component_cycles(row, dim, constants,
                            graph_quant=graph_quant)["total"]
    cbytes = beam_exchange_bytes(row, params, num_shards)
    ccycles = cbytes * constants.collective_per_byte
    straggler = 0.0
    if per_shard_storage:
        pens = [measured_miss_penalty(p, batch_q, constants)
                for p in per_shard_storage]
        straggler = max(pens) - float(np.mean(pens))
    cycles = base / max(int(num_shards), 1) + ccycles + straggler
    amp = 1.0 if threads <= 1 else 1.5
    qps = threads / (cycles * amp / clock_hz)
    return {"cycles_per_query": cycles, "base_cycles": base,
            "collective_bytes": cbytes, "collective_cycles": ccycles,
            "straggler_cycles": straggler, "modeled_qps": qps}


# ---------------------------------------------------------------------------
# Predictive mode (DESIGN.md §6).
#
# Closed-form EXPECTED Table 6 counters per strategy, as a function of the
# dataset/index shape, a per-batch selectivity estimate s (bitmap popcount
# / n) and a correlation proxy γ (local selectivity around the query ÷
# global selectivity; >1 = positively correlated predicate).  The effective
# selectivity s̃ = clip(s·γ, 1/n, 1) is what graph traversal locally sees.
#
# Calibration anchors (measured on the repo's strategies, see
# tests/test_executor.py and DESIGN.md §6 for the derivations):
#   * sweeping visits ~ef/s̃ hops before W fills with passing rows;
#   * iterative scan emits ~k/s̃ candidates before k pass the post-filter,
#     in batches of `batch_tuples`;
#   * each traversal hop newly scores ~GRAPH_NEW_PER_HOP rows (the rest of
#     the 2M neighborhood is already visited);
#   * filter-first checks all 2M 1-hop neighbors per hop and 2M more per
#     EXPANDED branch — non-passing branches under the hardened-ACORN skip,
#     a heuristic-gated fraction for NaviX.
# ---------------------------------------------------------------------------

GRAPH_NEW_PER_HOP = 2.5     # newly scored rows per hop (visited overlap)
SWEEP_FC_PER_DC = 0.6       # would-enter-W checks per scored row
NAVIX_EXPAND_FRAC = 0.5     # adaptive-heuristic 2-hop gating vs ACORN's 1.0
FILTER_FIRST_HOPS = 1.06    # hops ≈ FILTER_FIRST_HOPS · ef when connected
FILTER_FIRST_POOL = 0.7     # subgraph-exhaustion cap: hops ≤ 0.7·n·s̃
ITER_HOP_FACTOR = 1.6       # iterative-scan hops per emitted candidate
ITER_HOP_BASE = 40.0        # beam settle-down tail per scan round-trip

# Selectivity-aware tiers (DESIGN.md §14).  The exclusion-pruned sweeping
# law scales sweeping's hop count by an expected keep fraction: pruning
# only bites when the predicate is spatially clustered (γ > 1 — exclusion
# radii carry signal exactly when passing rows cluster), and bites harder
# the sparser the predicate.  EXCL_PRUNE_MAX is calibrated against the
# bench_filtercost clustered-family measurements (hop ratios 0.52–0.68 at
# s ∈ {0.02, 0.05}, margin 0.3).  At γ ≤ 1 the law degrades EXACTLY to
# sweeping's — an uncorrelated bitmap carries no exclusion signal, and the
# prediction must not promise savings the radii cannot deliver.
EXCL_PRUNE_MAX = 0.4        # asymptotic pruned hop fraction (γ → ∞)
# The partitioned tier's plan-time family match compares each query's
# bitmap against every registered family, word by word; the planner has
# no handle on the family count at predict time, so the law prices a
# nominal catalog.
PART_FAMILIES_EST = 4.0     # families assumed registered, for match fc
# One-off subgraph build work (≈ rows · ef_construction · 2 distance
# comps per inserted row), amortized per query over the horizon a hot
# predicate family is expected to serve before the partition goes stale.
PART_BUILD_DC_PER_ROW = 64.0
PART_AMORT_QUERIES = 50_000.0

PREDICTABLE_STRATEGIES = ("bruteforce", "scann", "sweeping", "acorn",
                          "navix", "iterative_scan", "unfiltered",
                          "sweeping_excl", "partitioned")

# Predictive-kind → graph-strategy family, for engine/quant/segment
# resolution: the exclusion tier runs the sweeping machinery, the
# partitioned tier runs unfiltered machinery on a subgraph.
GRAPH_KIND_ALIAS = {"sweeping_excl": "sweeping", "partitioned": "unfiltered"}


@dataclasses.dataclass(frozen=True)
class IndexShape:
    """Static shape facts the predictive model needs (SYSTEM-agnostic)."""

    n: int
    dim: int
    graph_m: int = 16                    # HNSW M; level-0 degree = 2M
    scann_leaves: Optional[int] = None   # L
    scann_rows_per_leaf: Optional[int] = None    # C (capacity, padded)
    scann_cent_scored: Optional[int] = None      # centroids scored (①+②)
    scann_pages_per_leaf: int = 1


def predict_counters(strategy: str, shape: IndexShape, params: SearchParams,
                     selectivity: float, correlation: float = 1.0,
                     batch_q: int = 1) -> dict[str, float]:
    """Expected per-query Table 6 counters for `strategy` (DESIGN.md §6).

    `batch_q` matters for scann under "batch" page accounting (DESIGN.md
    §5): the batched pipeline opens each leaf once per *batch*, so the
    expected per-query index pages shrink to E[unique leaves]/Q — with
    leaf choices modeled as uniform draws, E[unique] = L·(1−(1−nl/L)^Q).
    All other counters are per-query quantities under both modes."""
    n, k = shape.n, params.k
    ppv = heap_pages_per_vector(shape.dim)
    s = min(max(selectivity, 1.0 / n), 1.0)
    s_eff = min(max(s * max(correlation, 1e-3), 1.0 / n), 1.0)
    c = dict(distance_comps=0.0, filter_checks=0.0, hops=0.0,
             page_accesses_index=0.0, page_accesses_heap=0.0,
             tmap_lookups=0.0, reorder_rows=0.0)

    if strategy == "bruteforce":
        # seqscan over the bitmap: probe every row, fetch+score the passing
        c["filter_checks"] = float(n)
        c["distance_comps"] = s * n
        c["page_accesses_heap"] = s * n * ppv
        return c

    if strategy == "scann":
        if shape.scann_leaves is None or shape.scann_rows_per_leaf is None:
            raise ValueError("scann prediction needs scann_* shape facts")
        nl = min(params.num_leaves_to_search, shape.scann_leaves)
        rows = nl * shape.scann_rows_per_leaf
        r = min(k * params.reorder_factor, rows)
        cent = shape.scann_cent_scored or shape.scann_leaves
        c["filter_checks"] = float(rows)
        c["distance_comps"] = s_eff * rows + cent + r
        c["hops"] = float(nl)
        leaves_per_q = float(nl)
        if params.scann_page_accounting == "batch" and batch_q > 1:
            lf = float(shape.scann_leaves)
            uniq = lf * (1.0 - (1.0 - nl / lf) ** batch_q)
            leaves_per_q = min(uniq / batch_q, float(nl))
        c["page_accesses_index"] = leaves_per_q * shape.scann_pages_per_leaf
        c["page_accesses_heap"] = float(r * ppv)
        c["reorder_rows"] = float(r)
        return c

    deg = 2.0 * shape.graph_m
    ef = max(params.ef_search, 2 * k)
    tm = 1.0 if params.translation_map else 0.0

    def graph_quant_rerank(c: dict, r: float) -> dict:
        """SQ8 quantized-traversal transform (DESIGN.md §9): traversal
        rows fetch shadow pages (quant ppv), and the exact rerank of ~r
        beam entries adds r distance comps + r full-width heap pages,
        counted in reorder_rows — mirroring the engines' accounting."""
        if params.graph_quant != "sq8":
            return c
        qppv = quant_heap_pages_per_vector(shape.dim)
        trav_rows = c["page_accesses_heap"] / ppv
        c["page_accesses_heap"] = trav_rows * qppv + r * ppv
        c["distance_comps"] += r
        c["reorder_rows"] = r
        return c

    if strategy in ("sweeping", "unfiltered"):
        # traversal-first: W fills once ~ef passing rows were seen, and the
        # traversal sees passing rows at rate s̃ → ~ef/s̃ hops (capped by
        # max_hops and by graph exhaustion: ≲ n/NEW hops score all n rows).
        s_nav = 1.0 if strategy == "unfiltered" else s_eff
        hops = min(ef / s_nav, float(params.max_hops), n / GRAPH_NEW_PER_HOP)
        dc = min(GRAPH_NEW_PER_HOP * hops + ef, float(n))
        fc = 0.0 if strategy == "unfiltered" else SWEEP_FC_PER_DC * dc
        c.update(distance_comps=dc, filter_checks=fc, hops=hops,
                 page_accesses_index=hops + (1 - tm) * fc,
                 page_accesses_heap=dc * ppv, tmap_lookups=tm * fc)
        return graph_quant_rerank(c, float(ef))

    if strategy == "sweeping_excl":
        # FAVOR exclusion-pruned sweeping (DESIGN.md §14): sweeping's law
        # with hops scaled by the expected keep fraction.  corr_gain → 0
        # at γ ≤ 1 (uncorrelated radii prune nothing, the tier prices
        # exactly like sweeping) and → 1 as γ → ∞; sparser predicates
        # prune a larger branch fraction.  fc takes the same keep-fraction
        # discount — the prune_exact accounting's eliminated probes.
        corr_gain = max(0.0, 1.0 - 1.0 / max(correlation, 1.0))
        prune = EXCL_PRUNE_MAX * corr_gain * (1.0 - s)
        hops = min(ef / s_eff, float(params.max_hops),
                   n / GRAPH_NEW_PER_HOP) * (1.0 - prune)
        dc = min(GRAPH_NEW_PER_HOP * hops + ef, float(n))
        fc = SWEEP_FC_PER_DC * dc * (1.0 - prune)
        c.update(distance_comps=dc, filter_checks=fc, hops=hops,
                 page_accesses_index=hops + (1 - tm) * fc,
                 page_accesses_heap=dc * ppv, tmap_lookups=tm * fc)
        return graph_quant_rerank(c, float(ef))

    if strategy == "partitioned":
        # JAG attribute-partitioned subgraph (DESIGN.md §14): unfiltered
        # traversal over a private graph of n_f = s·n passing rows.  The
        # only filter work is the plan-time family match (every query's
        # bitmap against ~PART_FAMILIES_EST family bitmaps, n/32 words
        # each); per-candidate checks are gone by construction.  Build
        # amortization rides in predict_cycles (a cycle, not a counter).
        n_f = max(s * n, float(k))
        hops = min(float(ef), float(params.max_hops),
                   n_f / GRAPH_NEW_PER_HOP)
        dc = min(GRAPH_NEW_PER_HOP * hops + ef, n_f)
        fc = PART_FAMILIES_EST * math.ceil(n / 32)
        c.update(distance_comps=dc, filter_checks=fc, hops=hops,
                 page_accesses_index=hops,
                 page_accesses_heap=dc * ppv)
        return graph_quant_rerank(c, float(ef))

    if strategy == "iterative_scan":
        # pgvector post-filter: emit batches of `batch_tuples` unfiltered
        # candidates until k pass — E[emitted] ≈ k/s̃, rounded up to whole
        # batches, capped by the round budget.
        bt = params.batch_tuples
        emitted = float(min(bt * np.ceil((k / s_eff) / bt),
                            bt * params.max_rounds))
        hops = min(ITER_HOP_FACTOR * emitted + ITER_HOP_BASE,
                   float(params.max_hops), n / GRAPH_NEW_PER_HOP)
        dc = min(GRAPH_NEW_PER_HOP * hops, float(n))
        c.update(distance_comps=dc, filter_checks=emitted, hops=hops,
                 page_accesses_index=hops + (1 - tm) * emitted,
                 page_accesses_heap=dc * ppv, tmap_lookups=tm * emitted)
        return graph_quant_rerank(
            c, float(min(k * params.reorder_factor, emitted)))

    if strategy in ("acorn", "navix"):
        # filter-first: traversal stays on the predicate subgraph — hop
        # count is ~ef until the subgraph runs out of nodes; every hop
        # checks the full 1-hop neighborhood and 2M more per expanded
        # branch (hardened-ACORN expands the non-passing (1-s̃) fraction,
        # NaviX's adaptive heuristic a further NAVIX_EXPAND_FRAC of that).
        gate = 1.0 if strategy == "acorn" else NAVIX_EXPAND_FRAC
        if strategy == "navix" and s_eff > 0.35:
            gate = 0.05                      # adaptive-local: onehop zone
        hops = min(FILTER_FIRST_HOPS * ef, FILTER_FIRST_POOL * n * s_eff)
        hops = max(hops, 1.0)
        expand = deg * (1.0 - s_eff) * gate  # branches expanded per hop
        fc = hops * (deg + expand * deg)
        dc = min(hops * GRAPH_NEW_PER_HOP * (1.0 + gate), float(n))
        c.update(distance_comps=dc, filter_checks=fc, hops=hops,
                 page_accesses_index=hops * (1.0 + expand) + (1 - tm) * fc,
                 page_accesses_heap=dc * ppv, tmap_lookups=tm * fc)
        return graph_quant_rerank(c, float(ef))

    raise ValueError(f"no predictive model for strategy {strategy!r}")


def predict_cycles(strategy: str, shape: IndexShape, params: SearchParams,
                   selectivity: float, correlation: float = 1.0,
                   constants: CostConstants = SYSTEM,
                   batch_q: int = 1, pool_state=None,
                   measured_unique_frac: Optional[float] = None,
                   num_shards: int = 1) -> float:
    """Expected per-query modeled cycles (the planner's ranking metric).

    `batch_q` is the size of the query batch the plan will execute with:
    graph strategies under the frontier engine amortize page costs across
    the batch (`engine_scale`), and scann under "batch" accounting opens
    each leaf once per batch (`predict_counters`), so the planner's
    graph-vs-scann decision boundary tracks the engines that will
    actually run.

    `pool_state` (a storage.BufferPoolState) makes the prediction
    warm-cache-aware: expected buffer-pool misses — scaled by each
    segment's current residency — pay `page_miss_extra` on top of the hit
    cost (`cache_miss_penalty`).  None keeps the classic cold-blind
    prediction.

    `measured_unique_frac` feeds a pool-measured per-batch page-sharing
    fraction into `engine_scale`, replacing the FRONTIER_PAGE_AMORT
    constant with the measured amortization for frontier-engine graph
    strategies.  `params.graph_quant` ("sq8") prices the quantized
    traversal tier: cheaper int8 materialization + rerank surcharge
    (`component_cycles`), shadow-segment miss modeling
    (`cache_miss_penalty`)."""
    counters = predict_counters(strategy, shape, params, selectivity,
                                correlation, batch_q)
    # the selectivity-aware tiers run existing graph machinery (exclusion
    # = sweeping engine, partitioned = unfiltered on a subgraph), so
    # engine amortization, quant pricing, and segment attribution all
    # resolve through the aliased family
    gstrat = GRAPH_KIND_ALIAS.get(strategy, strategy)
    gq = params.graph_quant if gstrat in GRAPH_STRATEGIES else "none"
    base = component_cycles(
        counters, shape.dim, constants,
        engine_scale(gstrat, params, batch_q, measured_unique_frac),
        graph_quant=gq)["total"]
    total = base + cache_miss_penalty(counters, gstrat, pool_state,
                                      constants, graph_quant=gq,
                                      dim=shape.dim)
    if strategy == "partitioned":
        # one-off subgraph build work amortized per served query — keeps
        # the tier honest against a strategy that needs no extra artifact
        n_f = max(selectivity * shape.n, float(params.k))
        total += n_f * PART_BUILD_DC_PER_ROW * shape.dim \
            * constants.distance_per_dim / PART_AMORT_QUERIES
    if num_shards > 1 and gstrat in GRAPH_STRATEGIES:
        # Mesh-sharded frontier (DESIGN.md §13): scoring, fetches, and
        # the per-shard page streams all parallelize by row ownership;
        # the beam-exchange collective volume is the serial residue.
        total = total / num_shards \
            + beam_exchange_bytes(counters, params, num_shards) \
            * constants.collective_per_byte
    return total


# ---------------------------------------------------------------------------
# Anytime budgets (DESIGN.md §10).
#
# The deadline budget needs a cycle estimate INSIDE the jitted traversal
# loops, so it is priced with a pure linear form of the Table 6 counters —
# exactly `component_cycles` at scale=None / graph_quant="none", whose
# terms are all counter-proportional.  The post-hoc flag derivation
# (`evaluate_anytime`) applies the SAME weights to the final counters, so
# "the loop's deadline predicate fired" and "linear_cycles >= deadline"
# agree bit-for-bit for full-precision traversal.  Under sq8-with-rerank
# the post-loop exact rerank adds counters after the budget check, so the
# budget covers TOTAL per-query work and the flags are conservative
# (never a missed truncation; see DESIGN.md §10).
# ---------------------------------------------------------------------------

def budget_cycle_weights(dim: int, constants: CostConstants = SYSTEM
                         ) -> dict[str, float]:
    """Per-counter cycle weights of the linear cost form: cycles =
    Σ counter · weight.  Matches component_cycles(scale=None,
    graph_quant="none") exactly.  Plain python floats — safe to close
    over inside a jitted loop predicate."""
    return {
        "distance_comps": dim * constants.distance_per_dim
        + dim * 4 * constants.tuple_materialize,
        "filter_checks": constants.filter_check,
        "hops": 0.0,
        "page_accesses_index": constants.page_access,
        "page_accesses_heap": constants.page_access,
        "tmap_lookups": constants.tmap_lookup,
        "reorder_rows": constants.reorder_sort_per_row,
    }


def linear_cycles(stats: SearchStats, dim: int,
                  constants: CostConstants = SYSTEM) -> np.ndarray:
    """Per-query modeled cycles under the linear budget form — the
    post-hoc mirror of the in-loop deadline predicate (same float32
    arithmetic in the same term order, so flag derivation and the loop's
    stop decision agree at the boundary)."""
    w = budget_cycle_weights(dim, constants)
    d = stats.as_dict()
    out = None
    for name, weight in w.items():
        term = np.asarray(d[name], np.float32) * np.float32(weight)
        out = term if out is None else out + term
    return np.atleast_1d(out)


def evaluate_anytime(stats: Optional[SearchStats], params: SearchParams,
                     dim: int, ids, constants: CostConstants = SYSTEM,
                     hop_cap: Optional[int] = None,
                     extra_truncated: Optional[np.ndarray] = None,
                     extra_budget: Optional[np.ndarray] = None
                     ) -> AnytimeInfo:
    """Derive per-query AnytimeInfo flags from final counters (host-side).

    The graph loops check their stop predicates BEFORE each step, so at
    exit `hops == max_hops` iff the safety cap fired and
    `pages >= page_budget` iff the page predicate fired — the derivation
    is exact for graph_quant="none" (and conservative under
    sq8-with-rerank, whose post-loop rerank counters also count).

    hop_cap: the engine's safety cap (params.max_hops for graph
    executors); None for executors whose `hops` counter is not a
    traversal length (ScaNN counts leaves, bruteforce passing rows).
    extra_truncated / extra_budget: executor-supplied per-query masks for
    truncation the counters cannot show (e.g. a plan-level leaf clamp or
    a bruteforce partial-scan row cap).
    """
    ids = np.asarray(ids)
    completion = np.mean(ids >= 0, axis=-1, dtype=np.float32)
    completion = np.atleast_1d(completion)
    q = completion.shape[0]
    budget = np.zeros(q, bool)
    truncated = np.zeros(q, bool)
    if stats is not None:
        hops = np.atleast_1d(np.asarray(stats.hops, np.int64))
        pages = np.atleast_1d(
            np.asarray(stats.page_accesses_index, np.int64)
            + np.asarray(stats.page_accesses_heap, np.int64))
        if params.page_budget > 0:
            budget |= pages >= params.page_budget
        if params.hop_budget > 0:
            budget |= hops >= params.hop_budget
        if params.deadline_cycles > 0:
            budget |= linear_cycles(stats, dim, constants) \
                >= params.deadline_cycles
        if hop_cap is not None:
            truncated |= hops >= hop_cap
    if extra_budget is not None:
        budget |= np.atleast_1d(np.asarray(extra_budget, bool))
    truncated |= budget
    if extra_truncated is not None:
        truncated |= np.atleast_1d(np.asarray(extra_truncated, bool))
    return AnytimeInfo(truncated=truncated, budget_exhausted=budget,
                       completion=completion)


def queueing_delay_cycles(offered_per_cycle: float, service_cycles: float,
                          servers: int) -> float:
    """Expected queueing wait (modeled cycles) at an open-loop arrival
    rate of `offered_per_cycle` requests/cycle against `servers` slots
    each taking `service_cycles` per request.

    Sakasegawa's M/M/c approximation, Lq ≈ ρ^{√(2(c+1))} / (1 − ρ) with
    ρ = λ·S/c and Wq = Lq/λ, halved toward M/D/c since slot service times
    are tightly clustered within a deadline bucket.  Returns 0.0 when the
    system is idle (λ = 0) and +inf at or past saturation (ρ ≥ 1) — the
    admission gate treats an unstable operating point as an immediate
    reject, the same way a sub-floor deadline is (DESIGN.md §11)."""
    if offered_per_cycle <= 0.0 or service_cycles <= 0.0:
        return 0.0
    c = max(int(servers), 1)
    rho = offered_per_cycle * service_cycles / c
    if rho >= 1.0:
        return float("inf")
    lq = rho ** math.sqrt(2.0 * (c + 1)) / (1.0 - rho)
    return 0.5 * lq / offered_per_cycle


def queue_aware_floor(floor: float, queued: int, servers: int,
                      service_cycles: float) -> float:
    """Deadline admission floor inflated by the wait already visible in
    the arrival queue: `queued` requests ahead drain at roughly
    `servers` per `service_cycles`, so a request that would only meet
    its deadline on an empty queue is rejected instead of admitted to
    expire in line.  Degenerates to the plain `admission_floor` when the
    queue is empty."""
    if queued <= 0 or service_cycles <= 0.0:
        return floor
    return floor + (queued / max(int(servers), 1)) * service_cycles


def fault_penalty(storage_stats, batch_q: int,
                  constants: CostConstants = SYSTEM) -> float:
    """Per-query extra cycles from injected storage faults (a
    storage.StorageStats with fault counters) — recovery cost in the
    paper's own currency, matching `measured_miss_penalty`: every retry
    re-pays a miss-grade read and every latency spike pays the same
    page_miss_extra-style surcharge on top of the access it slowed."""
    extra = constants.page_access * (constants.page_miss_extra - 1.0)
    events = getattr(storage_stats, "retries", 0) \
        + getattr(storage_stats, "spikes", 0)
    return events * extra / max(batch_q, 1)


# ---------------------------------------------------------------------------
# Streaming mutability (DESIGN.md §12): the planner's price for a growing
# delta tier, and the write-side system-cost accounting.
# ---------------------------------------------------------------------------

def delta_scan_counters(n_delta: int, dim: int, selectivity: float,
                        k: int = 10) -> dict[str, float]:
    """Expected per-query Table-6 counters of the delta tier's exact scan
    (core.executor.DeltaExecutor) — seqscan semantics over the live delta
    rows: probe every one, fetch+score the passing."""
    ppv = heap_pages_per_vector(dim)
    s = min(max(selectivity, 0.0), 1.0)
    return dict(distance_comps=s * n_delta, filter_checks=float(n_delta),
                hops=0.0, page_accesses_index=0.0,
                page_accesses_heap=s * n_delta * ppv,
                tmap_lookups=0.0, reorder_rows=0.0)


def delta_scan_cycles(n_delta: int, dim: int, selectivity: float,
                      k: int = 10,
                      constants: CostConstants = SYSTEM) -> float:
    """Modeled per-query cycles the delta scan ADDS to whatever base
    strategy runs (the merge itself is O(k) and free at this scale).
    This is the term that makes a growing delta tier visible to the
    planner: every query pays it regardless of base strategy, so the
    compaction policy (`should_compact`) can weigh it against the one-off
    rebuild cost."""
    c = delta_scan_counters(n_delta, dim, selectivity, k)
    return component_cycles(c, dim, constants)["total"]


def write_amplification(user_bytes: int, page_writes: int,
                        wal_bytes: int = 0) -> float:
    """Physical-write bytes per logical user byte — the LSM tax, in the
    paper's page currency: (WAL bytes + 8 KB · page write-backs) /
    user payload bytes.  `page_writes` is the pool's write-back counter
    (PoolCounters.page_writes: dirty evictions + flushes), so checkpoint
    and compaction I/O land in the numerator exactly when they land on
    storage.  Returns inf when nothing was logically written but pages
    were, 1.0 when idle."""
    phys = wal_bytes + page_writes * PAGE_BYTES_WA
    if user_bytes <= 0:
        return float("inf") if phys > 0 else 1.0
    return phys / user_bytes


PAGE_BYTES_WA = 8192            # storage.pages.PAGE_BYTES (no import cycle)


def should_compact(n_delta: int, delta_capacity: int, n_base: int,
                   dim: int, selectivity: float,
                   queries_per_epoch: float = 1024.0,
                   fill_trigger: float = 0.75,
                   constants: CostConstants = SYSTEM) -> bool:
    """Compaction policy: fold the delta when (a) the tier is nearly full
    (capacity pressure — inserts would soon block), or (b) the scan tax
    the NEXT epoch of queries will pay on the delta exceeds the modeled
    one-off cost of rewriting the folded base (write amortization wins).
    The rebuild cost is priced as rewriting every base+delta heap page
    once at miss-grade cost — a deliberate underestimate of index
    rebuild work, so the policy leans eager the way LSM compactors do."""
    if n_delta <= 0:
        return False
    if n_delta >= fill_trigger * delta_capacity:
        return True
    scan_tax = queries_per_epoch * delta_scan_cycles(
        n_delta, dim, selectivity, constants=constants)
    ppv = heap_pages_per_vector(dim)
    rebuild = (n_base + n_delta) * ppv \
        * constants.page_access * constants.page_miss_extra
    return scan_tax > rebuild
