"""Filtered-vector-search workload generator (paper §4).

Given a vector dataset, a query set, a *selectivity* and a *correlation
type*, produces per-query row-id bitmaps simulating the result of evaluating
relational filter predicates — without materializing structured columns.

Correlation types (paper §4.2):
  high_pos   — softmax-biased sample from the closest THIRD of rows
  med_pos    — softmax-biased sample from the closest HALF
  low_pos    — softmax-biased sample from ALL rows (closer rows likelier)
  negative   — distances negated, then as low_pos (farther rows likelier)
  none       — uniform random sample

Sampling-without-replacement uses the Gumbel-top-k trick so the whole
generator is a single jittable program.  When the requested selectivity
exceeds the correlated pool size (e.g. 90 % selectivity with high_pos whose
pool is N/3), the full pool is taken and the remainder is drawn uniformly
from the rest — the maximum-feasible-correlation completion.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import VectorStore, distance, pack_bool_bitmap

CORRELATIONS = ("high_pos", "med_pos", "low_pos", "negative", "none")
# The paper's nine selectivities (§5 Workloads): 0.01 .. 0.9.
PAPER_SELECTIVITIES = (0.01, 0.02, 0.05, 0.1, 0.2, 0.3, 0.5, 0.8, 0.9)

_POOL_FRAC = {"high_pos": 1.0 / 3.0, "med_pos": 0.5, "low_pos": 1.0,
              "negative": 1.0, "none": 1.0}


def query_distance_order(store: VectorStore, queries: jax.Array,
                         block: int = 4096) -> jax.Array:
    """(Q, N) row ids sorted by increasing distance from each query."""
    dists = full_distances(store, queries, block)
    return jnp.argsort(dists, axis=-1)


def full_distances(store: VectorStore, queries: jax.Array,
                   block: int = 4096) -> jax.Array:
    """(Q, N) dense distance matrix, computed in row blocks."""
    q = jnp.asarray(queries, jnp.float32)
    n = store.n
    pads = (-n) % block
    vecs = jnp.pad(store.vectors, ((0, pads), (0, 0)))
    nsq = jnp.pad(store.norms_sq, (0, pads), constant_values=jnp.inf)
    nblocks = vecs.shape[0] // block

    def body(i, acc):
        rows = jax.lax.dynamic_slice_in_dim(vecs, i * block, block, 0)
        rnsq = jax.lax.dynamic_slice_in_dim(nsq, i * block, block, 0)
        d = distance(store.metric, q[:, None, :], rows[None, :, :], rnsq[None, :])
        return jax.lax.dynamic_update_slice_in_dim(acc, d, i * block, 1)

    acc = jnp.zeros((q.shape[0], nblocks * block), jnp.float32)
    out = jax.lax.fori_loop(0, nblocks, body, acc)
    return out[:, :n]


@partial(jax.jit, static_argnames=("n_sel", "pool_size", "negate", "uniform"))
def _sample_one(key, sorted_ids, sorted_dists, n_sel: int, pool_size: int,
                negate: bool, uniform: bool):
    """Gumbel-top-k biased sample of n_sel ids from the first pool_size rows."""
    n = sorted_ids.shape[0]
    pool_ids = sorted_ids[:pool_size]
    if uniform:
        logits = jnp.zeros((pool_size,))
    else:
        # Rank-based softmax bias (scale-free across datasets/metrics): the
        # closest row in the pool is e^BETA more likely than the farthest.
        # `negate` flips the ranking (negative correlation, paper §4.2).
        BETA = 4.0
        rank = jnp.arange(pool_size, dtype=jnp.float32)
        rank = (pool_size - 1) - rank if negate else rank
        logits = -BETA * rank / max(pool_size - 1, 1)
    k1, k2 = jax.random.split(key)
    gumbel = -jnp.log(-jnp.log(jax.random.uniform(k1, (pool_size,), minval=1e-20)))
    take_in_pool = min(n_sel, pool_size)
    _, idx = jax.lax.top_k(logits + gumbel, take_in_pool)
    chosen = pool_ids[idx]
    if n_sel > pool_size:
        # Maximum-feasible-correlation completion: whole pool + uniform rest.
        rest = sorted_ids[pool_size:]
        extra = jax.random.choice(k2, rest, (n_sel - pool_size,), replace=False)
        chosen = jnp.concatenate([chosen, extra])
    return chosen


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    selectivity: float
    correlation: str  # one of CORRELATIONS


def generate_bitmaps(store: VectorStore, queries: jax.Array,
                     spec: WorkloadSpec, seed: int = 0) -> jax.Array:
    """Per-query packed filter bitmaps (Q, ceil(N/32)) uint32."""
    rows = generate_passing_rows(store, queries, spec, seed)
    n = store.n
    out = []
    for r in rows:
        bits = np.zeros(n, bool)
        bits[np.asarray(r)] = True
        out.append(np.asarray(pack_bool_bitmap(bits)))
    return jnp.asarray(np.stack(out))


def generate_passing_rows(store: VectorStore, queries: jax.Array,
                          spec: WorkloadSpec, seed: int = 0) -> list[np.ndarray]:
    """Per-query arrays of row ids satisfying the simulated predicate."""
    if spec.correlation not in CORRELATIONS:
        raise ValueError(f"unknown correlation {spec.correlation!r}")
    if not (0.0 < spec.selectivity <= 1.0):
        raise ValueError("selectivity must be in (0, 1]")
    n = store.n
    n_sel = max(1, round(spec.selectivity * n))
    pool = max(n_sel if spec.correlation != "none" else 1,
               int(np.ceil(_POOL_FRAC[spec.correlation] * n)))
    pool = min(pool, n)
    dists = full_distances(store, queries)
    order = jnp.argsort(dists, axis=-1)
    sorted_d = jnp.take_along_axis(dists, order, axis=-1)
    keys = jax.random.split(jax.random.PRNGKey(seed), queries.shape[0])
    uniform = spec.correlation == "none"
    negate = spec.correlation == "negative"
    sample = jax.vmap(lambda k, oi, od: _sample_one(
        k, oi, od, n_sel=n_sel, pool_size=pool, negate=negate, uniform=uniform))
    chosen = sample(keys, order, sorted_d)
    return [np.asarray(c) for c in chosen]


def generate_grid(store: VectorStore, queries: jax.Array,
                  selectivities: Sequence[float] = PAPER_SELECTIVITIES,
                  correlations: Sequence[str] = CORRELATIONS,
                  seed: int = 0):
    """The paper's full workload grid: dict[(sel, corr)] -> (Q, words) bitmaps."""
    grid = {}
    for corr in correlations:
        for sel in selectivities:
            spec = WorkloadSpec(selectivity=sel, correlation=corr)
            grid[(sel, corr)] = generate_bitmaps(store, queries, spec, seed)
            seed += 1
    return grid


def generate_families(store: VectorStore, selectivity: float,
                      num_families: int = 2, seed: int = 0
                      ) -> dict[str, np.ndarray]:
    """Hot predicate *families* for the selectivity-aware tiers
    (DESIGN.md §14): spatially clustered passing sets shared by many
    queries — the regime FAVOR exclusion radii and JAG partitioned
    graphs are built for (a per-query-distinct bitmap can never be a
    registered family; an uncorrelated one carries no exclusion signal).

    Family f's passing set is the ceil(selectivity·n) nearest rows to a
    randomly drawn center row — the "category = c" predicate of a
    dataset whose attribute correlates with vector position.  Returns
    tag -> packed (W,) uint32 bitmap (np.ndarray, hashable-free build
    input for `build_exclusion`/`build_graph_partitioned`).
    """
    if not (0.0 < selectivity <= 1.0):
        raise ValueError("selectivity must be in (0, 1]")
    n = store.n
    n_sel = max(2, int(np.ceil(selectivity * n)))
    rng = np.random.RandomState(seed)
    centers = rng.choice(n, size=num_families, replace=False)
    cvecs = jnp.asarray(np.asarray(store.vectors)[centers])
    d = np.asarray(full_distances(store, cvecs))          # (F, N)
    out = {}
    for f, c in enumerate(centers):
        rows = np.argsort(d[f])[:n_sel]
        out[f"fam{f}_s{selectivity:g}"] = np.asarray(
            pack_bool_bitmap(np.isin(np.arange(n), rows)), np.uint32)
    return out


def assign_family_bitmaps(families: dict[str, np.ndarray], num_queries: int,
                          seed: int = 0) -> tuple[jax.Array, np.ndarray]:
    """Round-robin-free random assignment of queries to families: each
    query carries its family's shared bitmap verbatim (exact-match
    contract of the family tiers).  Returns ((Q, W) uint32 bitmaps,
    (Q,) int32 family index into sorted(families))."""
    tags = sorted(families)
    rng = np.random.RandomState(seed)
    assign = rng.randint(0, len(tags), size=num_queries).astype(np.int32)
    fam = np.stack([np.asarray(families[t], np.uint32) for t in tags])
    return jnp.asarray(fam[assign]), assign


def empirical_correlation(store: VectorStore, query: jax.Array,
                          passing_rows: np.ndarray, k: int = 100) -> float:
    """Fraction of the query's k unfiltered NNs that pass the filter —
    a direct measurable proxy for vector-predicate correlation (used by the
    property tests to assert the generator orders correlations correctly)."""
    d = full_distances(store, query[None])[0]
    nn = np.asarray(jnp.argsort(d)[:k])
    return float(np.isin(nn, passing_rows).mean())
