"""Training loop substrate: jitted step factory + fault-tolerant driver.

Fault-tolerance contract (DESIGN.md §6):
  * data is a pure function of (config, step) — restart replays exactly;
  * checkpoints are atomic + async (CheckpointManager), cadence-based;
  * on restart the Trainer resumes from the latest step, optionally onto a
    DIFFERENT mesh (elastic: restore_checkpoint reshards);
  * a per-step deadline hook flags stragglers: the loop records the stall
    and (configurably) skips the step — on real fleets this is where you'd
    trigger re-slicing; here the control flow is implemented and tested
    with an injectable clock/failure source.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, latest_step, \
    restore_checkpoint
from repro.models.api import ModelBundle
from repro.optim import AdamWConfig, adamw_init, adamw_update, \
    ef_compress_grads
from repro.optim.compression import init_error_buffers


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    microbatches: int = 1              # gradient accumulation factor
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    step_deadline_s: float = 0.0       # 0 = no deadline (straggler hook off)
    grad_compression: bool = False     # int8 error-feedback DP compression
    log_every: int = 10


def make_train_step(bundle: ModelBundle, opt_cfg: AdamWConfig,
                    train_cfg: TrainConfig,
                    donate: bool = True) -> Callable:
    """Returns jitted fn: (params, opt_state, batch) -> (params, opt_state,
    metrics).  With microbatches > 1, `batch` leaves carry a leading
    (microbatches, ...) axis and grads are accumulated with a scan."""

    def loss_fn(p, b):
        return bundle.loss(p, b)

    def step(params, opt_state, batch):
        if train_cfg.microbatches > 1:
            def acc(carry, mb):
                g_acc, l_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (grads, loss), _ = jax.lax.scan(acc, (zeros, 0.0), batch)
            scale = 1.0 / train_cfg.microbatches
            grads = jax.tree.map(lambda g: g * scale, grads)
            loss = loss * scale
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        if train_cfg.grad_compression:
            grads, err = ef_compress_grads(grads, opt_state["ef_error"])
        new_params, new_inner, metrics = adamw_update(
            params, grads, opt_state["adamw"], opt_cfg)
        new_state = {"adamw": new_inner}
        if train_cfg.grad_compression:
            new_state["ef_error"] = err
        metrics["loss"] = loss
        return new_params, new_state, metrics

    return jax.jit(step, donate_argnums=(0, 1) if donate else ())


def init_opt_state(bundle: ModelBundle, params: Any, opt_cfg: AdamWConfig,
                   train_cfg: TrainConfig) -> dict:
    state = {"adamw": adamw_init(params, opt_cfg)}
    if train_cfg.grad_compression:
        state["ef_error"] = init_error_buffers(params)
    return state


class Trainer:
    """Fault-tolerant driver around the jitted step."""

    def __init__(self, bundle: ModelBundle, opt_cfg: AdamWConfig,
                 train_cfg: TrainConfig,
                 batch_fn: Callable[[int], Any],
                 clock: Callable[[], float] = time.monotonic):
        self.bundle = bundle
        self.opt_cfg = opt_cfg
        self.cfg = train_cfg
        self.batch_fn = batch_fn
        self.clock = clock
        self.step_fn = make_train_step(bundle, opt_cfg, train_cfg)
        self.ckpt = (CheckpointManager(train_cfg.checkpoint_dir)
                     if train_cfg.checkpoint_dir else None)
        self.stragglers: list[int] = []
        self.history: list[dict] = []

    def init_or_restore(self, key, shardings: Optional[Any] = None):
        params = self.bundle.init(key)
        opt_state = init_opt_state(self.bundle, params, self.opt_cfg,
                                   self.cfg)
        start = 0
        if self.ckpt:
            last = latest_step(self.ckpt.directory)
            if last is not None:
                tree = {"params": params, "opt": opt_state}
                tree, extra = restore_checkpoint(
                    self.ckpt.directory, last, tree, shardings)
                params, opt_state = tree["params"], tree["opt"]
                start = last
        return params, opt_state, start

    def run(self, params, opt_state, start_step: int = 0,
            fail_at: Optional[int] = None):
        """fail_at injects a crash (tests exercise restart-and-replay)."""
        step = start_step
        while step < self.cfg.steps:
            if fail_at is not None and step == fail_at:
                raise RuntimeError(f"injected failure at step {step}")
            t0 = self.clock()
            batch = self.batch_fn(step)
            params, opt_state, metrics = self.step_fn(params, opt_state,
                                                      batch)
            dt = self.clock() - t0
            if self.cfg.step_deadline_s and dt > self.cfg.step_deadline_s:
                self.stragglers.append(step)
            step += 1
            if step % self.cfg.log_every == 0 or step == self.cfg.steps:
                self.history.append({"step": step,
                                     "loss": float(metrics["loss"]),
                                     "grad_norm": float(
                                         metrics["grad_norm"]),
                                     "sec": dt})
            if self.ckpt and (step % self.cfg.checkpoint_every == 0
                              or step == self.cfg.steps):
                self.ckpt.save_async(step, {"params": params,
                                            "opt": opt_state})
        if self.ckpt:
            self.ckpt.wait()
        return params, opt_state
